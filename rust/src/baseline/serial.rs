//! Serial engine — the Pandas / Julia DataFrames stand-in.
//!
//! Eager, single-threaded, columnar-vectorized (Pandas' C backend). The
//! split the paper highlights in §5 is preserved: built-in operations run
//! vectorized ([`filter`], [`aggregate`], [`sma`]), while user-lambda paths
//! ([`filter_udf_rows`], [`rolling_apply`]) walk rows through boxed
//! closures — reproducing the Pandas SMA-vs-WMA gap of Fig. 8b.

use crate::column::{normalize_mask, Column, NullableColumn, ValidityMask};
use crate::expr::{eval_mask, eval_nullable, AggExpr, Expr};
use crate::ir::WindowAgg;
use crate::ops::aggregate::{local_hash_aggregate_keys, AggSpec};
use crate::ops::join::local_join_pairs;
use crate::ops::keys::key_rows_nullable;
use crate::ops::stencil::stencil_serial;
use crate::ops::window::{partition_runs, window_group, window_over_groups};
use crate::table::{Schema, Table};
use crate::types::{JoinType, SortOrder};
use anyhow::{bail, Context, Result};

/// Vectorized filter (`df[df[:id] .< 100, :]`). Null predicate lanes drop
/// their row (SQL `WHERE` semantics) and column masks follow the filter.
pub fn filter(table: &Table, predicate: &Expr) -> Result<Table> {
    let keep = eval_mask(predicate, table)?;
    Ok(table.filter(&keep))
}

/// Row-lambda filter — the "any expression evaluating to Boolean" Pandas
/// path that is "not evaluated inside the optimized backend" (§5).
pub fn filter_udf_rows(table: &Table, f: &dyn Fn(&[f64]) -> bool, cols: &[&str]) -> Result<Table> {
    let inputs: Vec<Vec<f64>> = cols
        .iter()
        .map(|c| {
            table
                .column(c)
                .with_context(|| format!("no column {c}"))
                .map(|col| col.to_f64_vec())
        })
        .collect::<Result<_>>()?;
    let n = table.num_rows();
    let mut mask = Vec::with_capacity(n);
    for i in 0..n {
        // fresh argument buffer per row — the boxed-lambda cost
        let argv: Vec<f64> = inputs.iter().map(|c| c[i]).collect();
        mask.push(f(&argv));
    }
    Ok(table.filter(&mask))
}

/// Hash inner join (Pandas `merge`) — thin single-key wrapper over
/// [`join_on`].
pub fn join(left: &Table, right: &Table, lk: &str, rk: &str) -> Result<Table> {
    join_on(left, right, &[(lk, rk)], JoinType::Inner)
}

/// Composite-key hash join with join-type semantics (Pandas
/// `merge(on=[...], how=...)`). Mirrors the HiFrames engine exactly: output
/// key columns keep the left names and dtypes; the null-introduced side
/// keeps its native dtype and gains a validity mask; null keys match null
/// keys; Semi/Anti keep the left schema only.
pub fn join_on(
    left: &Table,
    right: &Table,
    on: &[(&str, &str)],
    how: JoinType,
) -> Result<Table> {
    if on.is_empty() {
        bail!("join: needs at least one key pair");
    }
    let lkey_cols: Vec<&Column> = on
        .iter()
        .map(|(lk, _)| left.column(lk).with_context(|| format!("join: left key {lk}")))
        .collect::<Result<_>>()?;
    let rkey_cols: Vec<&Column> = on
        .iter()
        .map(|(_, rk)| {
            right
                .column(rk)
                .with_context(|| format!("join: right key {rk}"))
        })
        .collect::<Result<_>>()?;
    let lkey_masks: Vec<Option<&ValidityMask>> =
        on.iter().map(|(lk, _)| left.mask(lk)).collect();
    let rkey_masks: Vec<Option<&ValidityMask>> =
        on.iter().map(|(_, rk)| right.mask(rk)).collect();
    for (lc, rc) in lkey_cols.iter().zip(&rkey_cols) {
        if lc.dtype() != rc.dtype() {
            bail!(
                "join: key pair dtype mismatch {} vs {}",
                lc.dtype(),
                rc.dtype()
            );
        }
        if !lc.dtype().is_groupable() {
            bail!("join key must be Int64/Bool/String, got {}", lc.dtype());
        }
    }
    let lrows = key_rows_nullable(&lkey_cols, &lkey_masks)?;
    let rrows = key_rows_nullable(&rkey_cols, &rkey_masks)?;
    let pairs = local_join_pairs(&lrows, &rrows, how);

    let lidx: Vec<Option<usize>> = pairs.iter().map(|&(lo, _)| lo).collect();
    let ridx: Vec<Option<usize>> = pairs.iter().map(|&(_, ro)| ro).collect();
    // unwrapped index vectors for the non-null-introducing sides, built once
    let li: Vec<usize> = if how.nullable_left() {
        Vec::new()
    } else {
        lidx.iter().map(|o| o.expect("left index")).collect()
    };
    let ri: Vec<usize> = if how.nullable_right() || !how.keeps_right_columns() {
        Vec::new()
    } else {
        ridx.iter().map(|o| o.expect("right index")).collect()
    };

    // static nullable flags — the same rule as the IR's join typing, so
    // engine-agreement tests can compare schemas exactly even when a
    // nullable column happens to carry no nulls
    let mut fields: Vec<(String, crate::types::DType)> = Vec::new();
    let mut nullable: Vec<bool> = Vec::new();
    let mut cols: Vec<Column> = Vec::new();
    let mut masks: Vec<Option<ValidityMask>> = Vec::new();
    let mut push = |n: &str, nl: bool, c: NullableColumn| {
        fields.push((n.to_string(), c.dtype()));
        nullable.push(nl);
        cols.push(c.values);
        masks.push(c.validity);
    };
    for (i, (n, t)) in left.schema().fields().iter().enumerate() {
        if let Some(j) = on.iter().position(|(lk, _)| *lk == n.as_str()) {
            // key slot: value + validity from whichever side is present
            let mut kc = Column::new_empty(*t);
            let mut km = ValidityMask::new_null(0);
            for &(lo, ro) in &pairs {
                let v = match (lo, ro) {
                    (Some(i), _) => kcell(lkey_cols[j], lkey_masks[j], i),
                    (None, Some(r)) => kcell(rkey_cols[j], rkey_masks[j], r),
                    (None, None) => unreachable!("join pair with no sides"),
                };
                crate::column::push_nullable(&mut kc, &mut km, &v);
            }
            let nl = left.schema().nullable_at(i)
                || right.schema().nullable_of(on[j].1).unwrap_or(false);
            push(n, nl, NullableColumn::new(kc, Some(km)));
        } else {
            let src = left.column(n).unwrap();
            let m = left.mask(n);
            let c = if how.nullable_left() {
                src.take_opt_masked(m, &lidx)
            } else {
                NullableColumn::new(src.take(&li), m.map(|m| m.take(&li)))
            };
            push(n, left.schema().nullable_at(i) || how.nullable_left(), c);
        }
    }
    if how.keeps_right_columns() {
        for (i, (n, _)) in right.schema().fields().iter().enumerate() {
            if on.iter().any(|(_, rk)| *rk == n.as_str()) {
                continue;
            }
            let src = right.column(n).unwrap();
            let m = right.mask(n);
            let c = if how.nullable_right() {
                src.take_opt_masked(m, &ridx)
            } else {
                NullableColumn::new(src.take(&ri), m.map(|m| m.take(&ri)))
            };
            push(n, right.schema().nullable_at(i) || how.nullable_right(), c);
        }
    }
    Table::new_masked(Schema::new_nullable(fields, nullable), cols, masks)
}

/// One key cell as a typed value (null when the mask bit is clear).
fn kcell(col: &Column, mask: Option<&ValidityMask>, i: usize) -> crate::types::Value {
    if mask.map_or(true, |m| m.get(i)) {
        col.get(i)
    } else {
        crate::types::Value::Null(col.dtype())
    }
}

/// Group-by aggregation (Pandas `groupby().agg`) — thin single-key wrapper
/// over [`aggregate_by`].
pub fn aggregate(table: &Table, key: &str, aggs: &[AggExpr]) -> Result<Table> {
    aggregate_by(table, &[key], aggs)
}

/// Composite-key group-by (Pandas `groupby([k1, k2]).agg`). Null keys form
/// their own group; null inputs are skipped by every reduction.
pub fn aggregate_by(table: &Table, keys: &[&str], aggs: &[AggExpr]) -> Result<Table> {
    let key_cols: Vec<(&Column, Option<&ValidityMask>)> = keys
        .iter()
        .map(|k| {
            table
                .column(k)
                .map(|c| (c, table.mask(k)))
                .with_context(|| format!("aggregate: key {k}"))
        })
        .collect::<Result<_>>()?;
    let mut expr_cols: Vec<(Column, Option<ValidityMask>)> = Vec::with_capacity(aggs.len());
    let mut specs = Vec::with_capacity(aggs.len());
    for a in aggs {
        let (c, m) = eval_nullable(&a.input, table)?;
        specs.push(AggSpec {
            func: a.func,
            input_dtype: c.dtype(),
        });
        expr_cols.push((c, m));
    }
    let expr_refs: Vec<(&Column, Option<&ValidityMask>)> = expr_cols
        .iter()
        .map(|(c, m)| (c, m.as_ref()))
        .collect();
    let (key_out, out_cols) = local_hash_aggregate_keys(&key_cols, &expr_refs, &specs)?;
    // static nullable flags, mirroring the IR's aggregate typing
    let mut nullable: Vec<bool> = keys
        .iter()
        .map(|k| table.schema().nullable_of(k).unwrap_or(false))
        .collect();
    for a in aggs {
        nullable.push(a.output_nullable(table.schema())?);
    }
    let mut fields = Vec::new();
    let mut cols = Vec::new();
    let mut masks = Vec::new();
    for (name, c) in keys
        .iter()
        .map(|k| k.to_string())
        .chain(aggs.iter().map(|a| a.out.clone()))
        .zip(key_out.into_iter().chain(out_cols))
    {
        fields.push((name, c.dtype()));
        cols.push(c.values);
        masks.push(c.validity);
    }
    Table::new_masked(Schema::new_nullable(fields, nullable), cols, masks)
}

/// Vertical concat.
pub fn concat(a: &Table, b: &Table) -> Result<Table> {
    a.concat(b)
}

/// Vectorized cumulative sum.
pub fn cumsum(table: &Table, column: &str, out: &str) -> Result<Table> {
    let src = table.column(column).context("cumsum col")?;
    let new = match src {
        Column::I64(v) => {
            let mut acc = 0i64;
            Column::I64(
                v.iter()
                    .map(|&x| {
                        acc += x;
                        acc
                    })
                    .collect(),
            )
        }
        other => {
            let v = other.to_f64_vec();
            let mut acc = 0.0;
            Column::F64(
                v.iter()
                    .map(|&x| {
                        acc += x;
                        acc
                    })
                    .collect(),
            )
        }
    };
    with_new_column(table, out, new)
}

/// Vectorized SMA (`rolling(w, center=True).mean()` — the fast Pandas path).
pub fn sma(table: &Table, column: &str, out: &str, window: usize) -> Result<Table> {
    let xs = table.column(column).context("sma col")?.to_f64_vec();
    let w = crate::ops::stencil::sma_weights(window);
    with_new_column(table, out, Column::F64(stencil_serial(&xs, &w)))
}

/// Row-lambda rolling window (`rolling(w).apply(lambda)` — the slow path).
/// The lambda sees the raw window (edge windows are truncated); weights
/// semantics must be applied by the lambda itself, exactly like Pandas.
pub fn rolling_apply(
    table: &Table,
    column: &str,
    out: &str,
    window: usize,
    f: &dyn Fn(&[f64]) -> f64,
) -> Result<Table> {
    assert!(window % 2 == 1);
    let xs = table.column(column).context("rolling col")?.to_f64_vec();
    let r = window / 2;
    let n = xs.len();
    let mut vals = Vec::with_capacity(n);
    for i in 0..n {
        let lo = i.saturating_sub(r);
        let hi = (i + r + 1).min(n);
        // per-row window copy through a boxed closure: the measured cost
        let win: Vec<f64> = xs[lo..hi].to_vec();
        vals.push(f(&win));
    }
    with_new_column(table, out, Column::F64(vals))
}

/// Vectorized WMA with explicit weights (matches HiFrames stencil
/// semantics: truncated + renormalized edges).
pub fn wma(table: &Table, column: &str, out: &str, weights: &[f64]) -> Result<Table> {
    let xs = table.column(column).context("wma col")?.to_f64_vec();
    with_new_column(table, out, Column::F64(stencil_serial(&xs, weights)))
}

/// Window functions (the Pandas `groupby().rolling()/shift/rank` family),
/// mirroring the HiFrames engine's semantics exactly: with partition keys
/// the rows are reordered by (partition keys asc nulls-first, order keys)
/// with a *stable* sort, then each aggregate runs per group; without them
/// the window is global in row order (and `order_by` must be empty).
pub fn window(
    table: &Table,
    partition_by: &[&str],
    order_by: &[(&str, SortOrder)],
    aggs: &[WindowAgg],
) -> Result<Table> {
    if partition_by.is_empty() && !order_by.is_empty() {
        bail!("window: order_by requires partition_by");
    }
    // evaluate the aggregate inputs over the *incoming* row order
    let mut expr_cols: Vec<(Column, Option<ValidityMask>)> = Vec::with_capacity(aggs.len());
    for a in aggs {
        expr_cols.push(eval_nullable(&a.input, table)?);
    }
    // reorder (partitioned) or keep (global)
    let n = table.num_rows();
    let (idx, group_starts, breaks): (Vec<usize>, Vec<usize>, Vec<bool>) =
        if partition_by.is_empty() {
            ((0..n).collect(), if n > 0 { vec![0] } else { vec![] }, vec![])
        } else {
            let mut key_cols: Vec<&Column> = Vec::new();
            let mut key_masks: Vec<Option<&ValidityMask>> = Vec::new();
            let mut orders: Vec<SortOrder> = Vec::new();
            for k in partition_by {
                key_cols.push(table.column(k).with_context(|| format!("window key {k}"))?);
                key_masks.push(table.mask(k));
                orders.push(SortOrder::Asc);
            }
            for (k, o) in order_by {
                key_cols.push(table.column(k).with_context(|| format!("window key {k}"))?);
                key_masks.push(table.mask(k));
                orders.push(*o);
            }
            let krows = key_rows_nullable(&key_cols, &key_masks)?;
            partition_runs(&krows, partition_by.len(), &orders)
        };
    // the global case keeps row order: a straight clone beats an
    // element-wise identity gather
    let reorder = |c: &Column, m: Option<&ValidityMask>| {
        if partition_by.is_empty() {
            (c.clone(), m.cloned())
        } else {
            (c.take(&idx), normalize_mask(m.map(|m| m.take(&idx))))
        }
    };
    // per-agg grouped kernels over the (re)ordered expression columns
    let mut outs: Vec<NullableColumn> = Vec::with_capacity(aggs.len());
    for (a, (ec, em)) in aggs.iter().zip(&expr_cols) {
        let (ec, em) = reorder(ec, em.as_ref());
        let breaks_opt = if partition_by.is_empty() {
            None
        } else {
            Some(breaks.as_slice())
        };
        outs.push(if partition_by.is_empty() {
            window_group(&ec, em.as_ref(), &a.frame, &a.func, breaks_opt)?
        } else {
            window_over_groups(
                &ec,
                em.as_ref(),
                &a.frame,
                &a.func,
                &group_starts,
                breaks_opt,
            )?
        });
    }
    // assemble: input fields minus replaced outs (reordered), then outs,
    // with the static nullable flags of the plan typing rule
    let mut fields: Vec<(String, crate::types::DType)> = Vec::new();
    let mut nullable: Vec<bool> = Vec::new();
    let mut cols: Vec<Column> = Vec::new();
    let mut masks: Vec<Option<ValidityMask>> = Vec::new();
    for (i, (name, t)) in table.schema().fields().iter().enumerate() {
        if aggs.iter().any(|a| &a.out == name) {
            continue;
        }
        let (c, m) = reorder(&table.columns()[i], table.mask_at(i));
        fields.push((name.clone(), *t));
        nullable.push(table.schema().nullable_at(i));
        cols.push(c);
        masks.push(m);
    }
    for (a, o) in aggs.iter().zip(outs) {
        let input_nullable = a.input.nullable(table.schema())?;
        fields.push((a.out.clone(), o.values.dtype()));
        nullable.push(a.func.output_nullable(&a.frame, input_nullable));
        cols.push(o.values);
        masks.push(o.validity);
    }
    Table::new_masked(Schema::new_nullable(fields, nullable), cols, masks)
}

fn with_new_column(table: &Table, out: &str, col: Column) -> Result<Table> {
    let mut pairs: Vec<(&str, Column)> = Vec::new();
    for (n, _) in table.schema().fields() {
        if n != out {
            pairs.push((n.as_str(), table.column(n).unwrap().clone()));
        }
    }
    pairs.push((out, col));
    Table::from_pairs(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit, AggFn};

    fn t() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2, 1, 3])),
            ("x", Column::F64(vec![0.5, 1.5, 2.5, 3.5])),
        ])
        .unwrap()
    }

    #[test]
    fn filter_both_paths_agree() {
        let a = filter(&t(), &col("x").gt(lit(1.0))).unwrap();
        let b = filter_udf_rows(&t(), &|v| v[0] > 1.0, &["x"]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.num_rows(), 3);
    }

    #[test]
    fn join_matches_expected() {
        let r = Table::from_pairs(vec![
            ("cid", Column::I64(vec![1, 3])),
            ("w", Column::I64(vec![10, 30])),
        ])
        .unwrap();
        let j = join(&t(), &r, "id", "cid").unwrap();
        assert_eq!(j.num_rows(), 3); // id 1 twice + id 3 once
        assert_eq!(j.schema().names(), vec!["id", "x", "w"]);
    }

    #[test]
    fn aggregate_matches() {
        let a = aggregate(
            &t(),
            "id",
            &[AggExpr::new("n", AggFn::Count, col("x"))],
        )
        .unwrap();
        let s = a.sorted_by("id").unwrap();
        assert_eq!(s.column("n").unwrap().as_i64(), &[2, 1, 1]);
    }

    #[test]
    fn left_join_and_multi_key_aggregate() {
        let r = Table::from_pairs(vec![
            ("cid", Column::I64(vec![1, 3])),
            ("w", Column::I64(vec![10, 30])),
        ])
        .unwrap();
        let j = join_on(&t(), &r, &[("id", "cid")], JoinType::Left).unwrap();
        assert_eq!(j.num_rows(), 4); // all left rows survive
        // dtype preserved; the unmatched row is masked null
        let w = j.column("w").unwrap().as_i64();
        assert_eq!(j.schema().nullable_of("w"), Some(true));
        // id column: [1, 2, 1, 3] → w = [10, null, 10, 30]
        assert_eq!(w[0], 10);
        assert!(!j.mask("w").unwrap().get(1));
        assert_eq!(w[1], 0, "null lane holds the default");
        assert_eq!(w[3], 30);
        // multi-key aggregate: group by (id, x>1) pairs
        let t2 = Table::from_pairs(vec![
            ("k1", Column::I64(vec![1, 1, 2])),
            ("k2", Column::I64(vec![0, 0, 1])),
            ("x", Column::F64(vec![1.0, 2.0, 3.0])),
        ])
        .unwrap();
        let a = aggregate_by(
            &t2,
            &["k1", "k2"],
            &[AggExpr::new("s", AggFn::Sum, col("x"))],
        )
        .unwrap();
        assert_eq!(a.num_rows(), 2);
        assert_eq!(a.schema().names(), vec!["k1", "k2", "s"]);
    }

    #[test]
    fn partitioned_window_orders_groups_and_shifts() {
        use crate::types::{WindowFrame, WindowFunc};
        let t2 = Table::from_pairs(vec![
            ("g", Column::I64(vec![1, 2, 1, 2, 1])),
            ("o", Column::I64(vec![5, 1, 3, 2, 4])),
            ("v", Column::I64(vec![10, 20, 30, 40, 50])),
        ])
        .unwrap();
        let aggs = vec![
            WindowAgg::new("prev", WindowFunc::Value, WindowFrame::Shift(1), col("v")),
            WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                col("v"),
            ),
            WindowAgg::new(
                "r",
                WindowFunc::Rank,
                WindowFrame::CumulativeToCurrent,
                lit(0i64),
            ),
        ];
        let out = window(
            &t2,
            &["g"],
            &[("o", crate::types::SortOrder::Asc)],
            &aggs,
        )
        .unwrap();
        // sorted: g=1 -> (o=3,v=30),(o=4,v=50),(o=5,v=10); g=2 -> (1,20),(2,40)
        assert_eq!(out.column("v").unwrap().as_i64(), &[30, 50, 10, 20, 40]);
        assert_eq!(out.column("prev").unwrap().as_i64(), &[0, 30, 50, 0, 20]);
        let m = out.mask("prev").unwrap();
        assert!(!m.get(0) && !m.get(3), "group heads are null");
        assert_eq!(out.column("cs").unwrap().as_i64(), &[30, 80, 90, 20, 60]);
        assert_eq!(out.column("r").unwrap().as_i64(), &[1, 2, 3, 1, 2]);
        // global window: row order preserved, order_by rejected
        let g = window(
            &t2,
            &[],
            &[],
            &[WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                col("v"),
            )],
        )
        .unwrap();
        assert_eq!(g.column("cs").unwrap().as_i64(), &[10, 30, 60, 100, 150]);
        assert!(window(
            &t2,
            &[],
            &[("o", crate::types::SortOrder::Asc)],
            &aggs
        )
        .is_err());
    }

    #[test]
    fn cumsum_and_windows() {
        let c = cumsum(&t(), "x", "cs").unwrap();
        assert_eq!(c.column("cs").unwrap().as_f64(), &[0.5, 2.0, 4.5, 8.0]);
        let s = sma(&t(), "x", "m", 3).unwrap();
        assert!((s.column("m").unwrap().as_f64()[1] - 1.5).abs() < 1e-12);
        // rolling_apply with mean lambda == vectorized sma
        let ra = rolling_apply(&t(), "x", "m", 3, &|w| {
            w.iter().sum::<f64>() / w.len() as f64
        })
        .unwrap();
        for (a, b) in ra
            .column("m")
            .unwrap()
            .as_f64()
            .iter()
            .zip(s.column("m").unwrap().as_f64())
        {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
