//! Scalar types shared across the whole stack.
//!
//! HiFrames (paper §4.1) annotates every data-frame column with a concrete
//! element type at the macro stage so Julia's type inference succeeds. Our
//! analogue: every [`crate::column::Column`] carries a [`DType`], and scalar
//! constants in expressions are [`Value`]s that must unify with the column
//! dtypes during expression type-checking.

use std::fmt;

/// Element type of a column.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 64-bit signed integer (Julia `Int64`).
    I64,
    /// 64-bit float (Julia `Float64`).
    F64,
    /// Boolean (filter masks, comparison results).
    Bool,
    /// UTF-8 string (dictionary columns in TPCx-BB tables).
    Str,
}

impl DType {
    /// Fixed per-element byte width used by the shuffle codec; strings are
    /// variable-width and report their average payload separately.
    pub fn fixed_width(self) -> Option<usize> {
        match self {
            DType::I64 | DType::F64 => Some(8),
            DType::Bool => Some(1),
            DType::Str => None,
        }
    }

    /// Is this a numeric type usable in arithmetic expressions?
    pub fn is_numeric(self) -> bool {
        matches!(self, DType::I64 | DType::F64)
    }

    /// The dtype arithmetic between two operands produces
    /// (int ⊕ float → float, like Julia's promotion rules).
    pub fn promote(self, other: DType) -> Option<DType> {
        match (self, other) {
            (DType::I64, DType::I64) => Some(DType::I64),
            (DType::F64, DType::F64)
            | (DType::I64, DType::F64)
            | (DType::F64, DType::I64) => Some(DType::F64),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::I64 => write!(f, "Int64"),
            DType::F64 => write!(f, "Float64"),
            DType::Bool => write!(f, "Bool"),
            DType::Str => write!(f, "String"),
        }
    }
}

/// A scalar value: expression literals, aggregate results, row cells in the
/// row-oriented baseline engine.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn dtype(&self) -> DType {
        match self {
            Value::I64(_) => DType::I64,
            Value::F64(_) => DType::F64,
            Value::Bool(_) => DType::Bool,
            Value::Str(_) => DType::Str,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::I64(v) => Some(*v as f64),
            Value::F64(v) => Some(*v),
            Value::Bool(b) => Some(*b as i64 as f64),
            Value::Str(_) => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::I64(v) => Some(*v),
            Value::F64(v) => Some(*v as i64),
            Value::Bool(b) => Some(*b as i64),
            Value::Str(_) => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::I64(v) => write!(f, "{v}"),
            Value::F64(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "{v}"),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::I64(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_promotion() {
        assert_eq!(DType::I64.promote(DType::I64), Some(DType::I64));
        assert_eq!(DType::I64.promote(DType::F64), Some(DType::F64));
        assert_eq!(DType::F64.promote(DType::I64), Some(DType::F64));
        assert_eq!(DType::Bool.promote(DType::I64), None);
        assert_eq!(DType::Str.promote(DType::Str), None);
    }

    #[test]
    fn dtype_widths() {
        assert_eq!(DType::I64.fixed_width(), Some(8));
        assert_eq!(DType::F64.fixed_width(), Some(8));
        assert_eq!(DType::Bool.fixed_width(), Some(1));
        assert_eq!(DType::Str.fixed_width(), None);
    }

    #[test]
    fn value_conversions() {
        assert_eq!(Value::from(3i64).as_f64(), Some(3.0));
        assert_eq!(Value::from(2.5f64).as_i64(), Some(2));
        assert_eq!(Value::from(true).as_f64(), Some(1.0));
        assert_eq!(Value::from("x").as_f64(), None);
        assert_eq!(Value::from(false).as_bool(), Some(false));
        assert_eq!(Value::from(1i64).as_bool(), None);
    }

    #[test]
    fn value_dtype_roundtrip() {
        for v in [
            Value::I64(1),
            Value::F64(1.0),
            Value::Bool(true),
            Value::Str("a".into()),
        ] {
            let d = v.dtype();
            assert_eq!(format!("{d}").is_empty(), false);
        }
    }

    #[test]
    fn display() {
        assert_eq!(Value::I64(7).to_string(), "7");
        assert_eq!(Value::Str("hi".into()).to_string(), "hi");
        assert_eq!(DType::F64.to_string(), "Float64");
    }
}
