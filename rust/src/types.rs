//! Scalar types shared across the whole stack.
//!
//! HiFrames (paper §4.1) annotates every data-frame column with a concrete
//! element type at the macro stage so Julia's type inference succeeds. Our
//! analogue: every [`crate::column::Column`] carries a [`DType`], and scalar
//! constants in expressions are [`Value`]s that must unify with the column
//! dtypes during expression type-checking.

use std::fmt;

/// Element type of a column.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 64-bit signed integer (Julia `Int64`).
    I64,
    /// 64-bit float (Julia `Float64`).
    F64,
    /// Boolean (filter masks, comparison results).
    Bool,
    /// UTF-8 string (dictionary columns in TPCx-BB tables).
    Str,
}

impl DType {
    /// Fixed per-element byte width used by the shuffle codec; strings are
    /// variable-width and report their average payload separately.
    pub fn fixed_width(self) -> Option<usize> {
        match self {
            DType::I64 | DType::F64 => Some(8),
            DType::Bool => Some(1),
            DType::Str => None,
        }
    }

    /// Is this a numeric type usable in arithmetic expressions?
    pub fn is_numeric(self) -> bool {
        matches!(self, DType::I64 | DType::F64)
    }

    /// Can this dtype serve as a join / group-by / sort key? Keys need total
    /// order and hashable equality, which excludes Float64 (NaN).
    pub fn is_groupable(self) -> bool {
        matches!(self, DType::I64 | DType::Bool | DType::Str)
    }

    /// The canonical value stored under a null lane (the validity-mask null
    /// model keeps native dtypes; invalid rows hold this default so every
    /// engine agrees byte-for-byte on masked columns).
    pub fn default_value(self) -> Value {
        match self {
            DType::I64 => Value::I64(0),
            DType::F64 => Value::F64(0.0),
            DType::Bool => Value::Bool(false),
            DType::Str => Value::Str(String::new()),
        }
    }

    /// The dtype arithmetic between two operands produces
    /// (int ⊕ float → float, like Julia's promotion rules).
    pub fn promote(self, other: DType) -> Option<DType> {
        match (self, other) {
            (DType::I64, DType::I64) => Some(DType::I64),
            (DType::F64, DType::F64)
            | (DType::I64, DType::F64)
            | (DType::F64, DType::I64) => Some(DType::F64),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::I64 => write!(f, "Int64"),
            DType::F64 => write!(f, "Float64"),
            DType::Bool => write!(f, "Bool"),
            DType::Str => write!(f, "String"),
        }
    }
}

/// Join semantics of [`crate::ir::Plan::Join`] (the composite-key relational
/// redesign). `Inner` is the paper's `join(df1, df2, :id == :cid)`; the
/// others cover the TPCx-BB shapes the kit queries need (sparse dimensions →
/// `Left`, existence tests → `Semi`/`Anti`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JoinType {
    /// Keep only matching key pairs (cross product within equal keys).
    Inner,
    /// Every left row survives; unmatched rows get null-introduced right
    /// columns (native dtype + validity mask).
    Left,
    /// Every right row survives; unmatched rows get null-introduced left
    /// columns.
    Right,
    /// Union of `Left` and `Right`.
    Outer,
    /// Left rows with at least one match; right columns are dropped.
    Semi,
    /// Left rows with no match; right columns are dropped.
    Anti,
}

impl JoinType {
    /// Do unmatched rows introduce nulls into *left*-side columns?
    pub fn nullable_left(self) -> bool {
        matches!(self, JoinType::Right | JoinType::Outer)
    }

    /// Do unmatched rows introduce nulls into *right*-side columns?
    pub fn nullable_right(self) -> bool {
        matches!(self, JoinType::Left | JoinType::Outer)
    }

    /// Does the output carry the right side's non-key columns at all?
    pub fn keeps_right_columns(self) -> bool {
        !matches!(self, JoinType::Semi | JoinType::Anti)
    }
}

impl fmt::Display for JoinType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            JoinType::Inner => "inner",
            JoinType::Left => "left",
            JoinType::Right => "right",
            JoinType::Outer => "outer",
            JoinType::Semi => "semi",
            JoinType::Anti => "anti",
        };
        write!(f, "{s}")
    }
}

/// Physical distribution strategy of a [`crate::ir::Plan::Join`] — the IR
/// hint the skew-aware join subsystem is keyed on. The planner pass flips
/// `Hash` to `SkewBroadcast` when source statistics show a heavy-hitter key
/// distribution; users can force either via the join builder
/// (`df.join_with(..).skew_hint(..)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum JoinStrategy {
    /// Hash-partition both sides by their key tuple (the default; the
    /// paper's `_df_id[i] % npes` routing).
    #[default]
    Hash,
    /// Skew-aware: a sampling pass estimates per-key frequency at run time;
    /// keys whose global frequency share exceeds
    /// `threshold_permille / 1000` take a broadcast path (heavy build-side
    /// rows replicated to every rank, heavy probe-side rows kept local),
    /// while light keys go through the ordinary hash shuffle. The threshold
    /// is stored in per-mille so the strategy stays `Copy + Eq + Hash`.
    SkewBroadcast {
        /// Heavy-hitter frequency threshold, in thousandths (1..=1000).
        threshold_permille: u16,
    },
}

impl JoinStrategy {
    /// Default heavy-hitter threshold: a key holding ≥ 10 % of the probe
    /// side concentrates at least that share of the join on one rank under
    /// hash partitioning, which already dominates wall-clock at ≥ 4 ranks.
    pub const DEFAULT_SKEW_THRESHOLD_PERMILLE: u16 = 100;

    /// `SkewBroadcast` with the default threshold.
    pub fn skew_default() -> JoinStrategy {
        JoinStrategy::SkewBroadcast {
            threshold_permille: JoinStrategy::DEFAULT_SKEW_THRESHOLD_PERMILLE,
        }
    }

    /// `SkewBroadcast` with a fractional threshold (clamped to
    /// `[0.001, 1.0]`; ±infinity clamps like any other out-of-range value,
    /// while `NaN` — which would slip through the clamp and cast to 0,
    /// classifying every sampled key as heavy — falls back to the default).
    pub fn skew_with_threshold(threshold: f64) -> JoinStrategy {
        let permille = if threshold.is_nan() {
            JoinStrategy::DEFAULT_SKEW_THRESHOLD_PERMILLE
        } else {
            (threshold * 1000.0).round().clamp(1.0, 1000.0) as u16
        };
        JoinStrategy::SkewBroadcast {
            threshold_permille: permille,
        }
    }

    /// The heavy-hitter frequency threshold as a fraction, or `None` for
    /// the plain hash strategy.
    pub fn threshold(self) -> Option<f64> {
        match self {
            JoinStrategy::Hash => None,
            JoinStrategy::SkewBroadcast { threshold_permille } => {
                Some(threshold_permille as f64 / 1000.0)
            }
        }
    }
}

impl fmt::Display for JoinStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JoinStrategy::Hash => write!(f, "hash"),
            JoinStrategy::SkewBroadcast { threshold_permille } => {
                write!(f, "skew-broadcast({}/1000)", threshold_permille)
            }
        }
    }
}

/// Frame specification of a window computation — which rows around row `i`
/// feed its output (the unified analytics surface subsuming the former
/// `cumsum`/`stencil` special cases). Frames are *row-based* (`ROWS
/// BETWEEN`), matching the paper's 1D-block stencil/scan codegen.
#[derive(Debug, Clone, PartialEq)]
pub enum WindowFrame {
    /// `ROWS BETWEEN preceding PRECEDING AND following FOLLOWING` — the
    /// current row is always included, so the frame is never empty. Edge
    /// windows truncate to the rows that exist (Pandas `min_periods=1`;
    /// the weighted function additionally renormalizes, keeping the old
    /// stencil semantics bit-for-bit).
    Rolling { preceding: usize, following: usize },
    /// `ROWS UNBOUNDED PRECEDING .. CURRENT ROW` — running scans
    /// (cumulative sum/min/max/…), lowered to `MPI_Exscan` instead of a
    /// halo exchange.
    CumulativeToCurrent,
    /// The single row at `i - offset`: positive = lag, negative = lead,
    /// zero = identity. Out-of-range rows (the leading/trailing `|offset|`
    /// edge) produce NULL via the validity mask.
    Shift(i64),
}

impl WindowFrame {
    /// Rows needed from before/after the local block — the halo widths of
    /// the distributed lowering. Scans need no halo (they use `exscan`).
    pub fn halo(&self) -> (usize, usize) {
        match self {
            WindowFrame::Rolling {
                preceding,
                following,
            } => (*preceding, *following),
            WindowFrame::CumulativeToCurrent => (0, 0),
            WindowFrame::Shift(k) => {
                if *k >= 0 {
                    (*k as usize, 0)
                } else {
                    (0, k.unsigned_abs() as usize)
                }
            }
        }
    }
}

impl fmt::Display for WindowFrame {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WindowFrame::Rolling {
                preceding,
                following,
            } => write!(f, "rolling[{preceding},{following}]"),
            WindowFrame::CumulativeToCurrent => write!(f, "cumulative"),
            WindowFrame::Shift(k) => write!(f, "shift({k})"),
        }
    }
}

/// Aggregate/projection function applied over a [`WindowFrame`].
#[derive(Debug, Clone, PartialEq)]
pub enum WindowFunc {
    /// Sum of the valid frame rows (0 when all are null — never NULL).
    Sum,
    /// Mean of the valid frame rows (NULL when all are null).
    Mean,
    /// Min of the valid frame rows (NULL when all are null).
    Min,
    /// Max of the valid frame rows (NULL when all are null).
    Max,
    /// Number of valid frame rows (never NULL).
    Count,
    /// Weighted combination `Σ w[j]·x[i+j-preceding]` with truncated edges
    /// renormalized by the weight mass actually used — the WMA/SMA stencil.
    /// Requires a [`WindowFrame::Rolling`] frame whose width equals
    /// `weights.len()`. Null lanes are skipped and renormalized away, so a
    /// nullable input yields NULL only for an all-null frame.
    Weighted(Vec<f64>),
    /// The frame's single value itself — the function of `shift`/`lag`/
    /// `lead`. Requires a [`WindowFrame::Shift`] frame.
    Value,
    /// Competition rank (1,1,3,…) of the row within its partition under the
    /// window's `order_by` keys. Requires a non-empty `order_by`; the frame
    /// is ignored. Never NULL.
    Rank,
    /// 1-based position of the row within its partition (global row number
    /// for an un-partitioned window). The frame is ignored. Never NULL.
    RowNumber,
}

impl WindowFunc {
    /// Output dtype given the input expression's dtype.
    pub fn output_dtype(&self, input: DType) -> DType {
        match self {
            WindowFunc::Sum | WindowFunc::Min | WindowFunc::Max | WindowFunc::Value => input,
            WindowFunc::Mean | WindowFunc::Weighted(_) => DType::F64,
            WindowFunc::Count | WindowFunc::Rank | WindowFunc::RowNumber => DType::I64,
        }
    }

    /// Does this function require a numeric input column?
    pub fn needs_numeric_input(&self) -> bool {
        matches!(
            self,
            WindowFunc::Sum
                | WindowFunc::Mean
                | WindowFunc::Min
                | WindowFunc::Max
                | WindowFunc::Weighted(_)
        )
    }

    /// Does the output ignore the input values entirely (pure position
    /// functions)?
    pub fn is_positional(&self) -> bool {
        matches!(self, WindowFunc::Rank | WindowFunc::RowNumber)
    }

    /// May the output be NULL, given the frame and the input nullability?
    /// `sum`/`count` have natural empty values (0) and the position
    /// functions never look at values; `mean`/`min`/`max`/`weighted` go
    /// NULL on an all-null frame; a non-trivial shift always nulls its
    /// leading/trailing edge.
    pub fn output_nullable(&self, frame: &WindowFrame, input_nullable: bool) -> bool {
        if let WindowFrame::Shift(k) = frame {
            return *k != 0 || input_nullable;
        }
        match self {
            WindowFunc::Sum
            | WindowFunc::Count
            | WindowFunc::Rank
            | WindowFunc::RowNumber => false,
            WindowFunc::Mean
            | WindowFunc::Min
            | WindowFunc::Max
            | WindowFunc::Weighted(_)
            | WindowFunc::Value => input_nullable,
        }
    }
}

impl fmt::Display for WindowFunc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WindowFunc::Sum => write!(f, "sum"),
            WindowFunc::Mean => write!(f, "mean"),
            WindowFunc::Min => write!(f, "min"),
            WindowFunc::Max => write!(f, "max"),
            WindowFunc::Count => write!(f, "count"),
            WindowFunc::Weighted(w) => write!(f, "weighted({} taps)", w.len()),
            WindowFunc::Value => write!(f, "value"),
            WindowFunc::Rank => write!(f, "rank"),
            WindowFunc::RowNumber => write!(f, "row_number"),
        }
    }
}

/// Per-key sort direction for [`crate::ir::Plan::Sort`]'s key list.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SortOrder {
    Asc,
    Desc,
}

impl fmt::Display for SortOrder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SortOrder::Asc => write!(f, "asc"),
            SortOrder::Desc => write!(f, "desc"),
        }
    }
}

/// A scalar value: expression literals, aggregate results, row cells in the
/// row-oriented baseline engine. [`Value::Null`] is a *typed* null — the
/// row-engine counterpart of a cleared validity-mask bit (it remembers its
/// column dtype so schemas survive the row path).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
    /// A missing value of the given column dtype.
    Null(DType),
}

impl Value {
    pub fn dtype(&self) -> DType {
        match self {
            Value::I64(_) => DType::I64,
            Value::F64(_) => DType::F64,
            Value::Bool(_) => DType::Bool,
            Value::Str(_) => DType::Str,
            Value::Null(dt) => *dt,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null(_))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::I64(v) => Some(*v as f64),
            Value::F64(v) => Some(*v),
            Value::Bool(b) => Some(*b as i64 as f64),
            Value::Str(_) | Value::Null(_) => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::I64(v) => Some(*v),
            Value::F64(v) => Some(*v as i64),
            Value::Bool(b) => Some(*b as i64),
            Value::Str(_) | Value::Null(_) => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::I64(v) => write!(f, "{v}"),
            Value::F64(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "{v}"),
            Value::Null(_) => write!(f, "null"),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::I64(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_promotion() {
        assert_eq!(DType::I64.promote(DType::I64), Some(DType::I64));
        assert_eq!(DType::I64.promote(DType::F64), Some(DType::F64));
        assert_eq!(DType::F64.promote(DType::I64), Some(DType::F64));
        assert_eq!(DType::Bool.promote(DType::I64), None);
        assert_eq!(DType::Str.promote(DType::Str), None);
    }

    #[test]
    fn dtype_groupable_and_defaults() {
        assert!(DType::I64.is_groupable());
        assert!(DType::Str.is_groupable());
        assert!(DType::Bool.is_groupable());
        assert!(!DType::F64.is_groupable());
        assert_eq!(DType::I64.default_value(), Value::I64(0));
        assert_eq!(DType::Bool.default_value(), Value::Bool(false));
        assert_eq!(DType::Str.default_value(), Value::Str(String::new()));
        assert_eq!(DType::F64.default_value(), Value::F64(0.0));
    }

    #[test]
    fn join_strategy_threshold_and_display() {
        assert_eq!(JoinStrategy::default(), JoinStrategy::Hash);
        assert_eq!(JoinStrategy::Hash.threshold(), None);
        assert_eq!(
            JoinStrategy::skew_default().threshold(),
            Some(JoinStrategy::DEFAULT_SKEW_THRESHOLD_PERMILLE as f64 / 1000.0)
        );
        assert_eq!(
            JoinStrategy::skew_with_threshold(0.25),
            JoinStrategy::SkewBroadcast {
                threshold_permille: 250
            }
        );
        // clamping at both ends
        assert_eq!(
            JoinStrategy::skew_with_threshold(0.0),
            JoinStrategy::SkewBroadcast {
                threshold_permille: 1
            }
        );
        assert_eq!(
            JoinStrategy::skew_with_threshold(9.0),
            JoinStrategy::SkewBroadcast {
                threshold_permille: 1000
            }
        );
        // NaN falls back to the default instead of casting to 0; ±infinity
        // clamps like any other out-of-range value
        assert_eq!(
            JoinStrategy::skew_with_threshold(f64::NAN),
            JoinStrategy::skew_default()
        );
        assert_eq!(
            JoinStrategy::skew_with_threshold(f64::INFINITY),
            JoinStrategy::SkewBroadcast {
                threshold_permille: 1000
            }
        );
        assert_eq!(
            JoinStrategy::skew_with_threshold(f64::NEG_INFINITY),
            JoinStrategy::SkewBroadcast {
                threshold_permille: 1
            }
        );
        assert_eq!(JoinStrategy::Hash.to_string(), "hash");
        assert_eq!(
            JoinStrategy::skew_default().to_string(),
            "skew-broadcast(100/1000)"
        );
    }

    #[test]
    fn join_type_flags() {
        assert!(JoinType::Left.nullable_right());
        assert!(!JoinType::Left.nullable_left());
        assert!(JoinType::Right.nullable_left());
        assert!(JoinType::Outer.nullable_left() && JoinType::Outer.nullable_right());
        assert!(!JoinType::Inner.nullable_left() && !JoinType::Inner.nullable_right());
        assert!(!JoinType::Semi.keeps_right_columns());
        assert!(!JoinType::Anti.keeps_right_columns());
        assert!(JoinType::Left.keeps_right_columns());
        assert_eq!(JoinType::Semi.to_string(), "semi");
        assert_eq!(SortOrder::Desc.to_string(), "desc");
    }

    #[test]
    fn dtype_widths() {
        assert_eq!(DType::I64.fixed_width(), Some(8));
        assert_eq!(DType::F64.fixed_width(), Some(8));
        assert_eq!(DType::Bool.fixed_width(), Some(1));
        assert_eq!(DType::Str.fixed_width(), None);
    }

    #[test]
    fn value_conversions() {
        assert_eq!(Value::from(3i64).as_f64(), Some(3.0));
        assert_eq!(Value::from(2.5f64).as_i64(), Some(2));
        assert_eq!(Value::from(true).as_f64(), Some(1.0));
        assert_eq!(Value::from("x").as_f64(), None);
        assert_eq!(Value::from(false).as_bool(), Some(false));
        assert_eq!(Value::from(1i64).as_bool(), None);
    }

    #[test]
    fn value_dtype_roundtrip() {
        for v in [
            Value::I64(1),
            Value::F64(1.0),
            Value::Bool(true),
            Value::Str("a".into()),
            Value::Null(DType::I64),
        ] {
            let d = v.dtype();
            assert_eq!(format!("{d}").is_empty(), false);
        }
    }

    #[test]
    fn display() {
        assert_eq!(Value::I64(7).to_string(), "7");
        assert_eq!(Value::Str("hi".into()).to_string(), "hi");
        assert_eq!(DType::F64.to_string(), "Float64");
        assert_eq!(Value::Null(DType::Str).to_string(), "null");
    }

    #[test]
    fn window_frame_halos_and_typing() {
        assert_eq!(
            WindowFrame::Rolling {
                preceding: 2,
                following: 1
            }
            .halo(),
            (2, 1)
        );
        assert_eq!(WindowFrame::CumulativeToCurrent.halo(), (0, 0));
        assert_eq!(WindowFrame::Shift(3).halo(), (3, 0));
        assert_eq!(WindowFrame::Shift(-2).halo(), (0, 2));
        assert_eq!(WindowFunc::Sum.output_dtype(DType::I64), DType::I64);
        assert_eq!(WindowFunc::Mean.output_dtype(DType::I64), DType::F64);
        assert_eq!(WindowFunc::Count.output_dtype(DType::F64), DType::I64);
        assert_eq!(WindowFunc::Value.output_dtype(DType::Str), DType::Str);
        let roll = WindowFrame::Rolling {
            preceding: 1,
            following: 1,
        };
        // sum/count never null; mean/min/max follow the input; shift edges null
        assert!(!WindowFunc::Sum.output_nullable(&roll, true));
        assert!(!WindowFunc::Count.output_nullable(&roll, true));
        assert!(WindowFunc::Mean.output_nullable(&roll, true));
        assert!(!WindowFunc::Min.output_nullable(&roll, false));
        assert!(WindowFunc::Value.output_nullable(&WindowFrame::Shift(1), false));
        assert!(!WindowFunc::Value.output_nullable(&WindowFrame::Shift(0), false));
        assert!(WindowFunc::Rank.is_positional());
        assert!(WindowFunc::Weighted(vec![1.0]).needs_numeric_input());
        assert_eq!(WindowFrame::Shift(-1).to_string(), "shift(-1)");
        assert_eq!(
            WindowFrame::Rolling {
                preceding: 2,
                following: 0
            }
            .to_string(),
            "rolling[2,0]"
        );
    }

    #[test]
    fn null_value_semantics() {
        let n = Value::Null(DType::I64);
        assert!(n.is_null());
        assert_eq!(n.dtype(), DType::I64);
        assert_eq!(n.as_f64(), None);
        assert_eq!(n.as_i64(), None);
        assert_eq!(n.as_bool(), None);
        assert!(!Value::I64(0).is_null());
    }
}
