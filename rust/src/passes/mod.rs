//! The HiFrames compiler pipeline (paper Fig. 1).
//!
//! | Paper pass        | Here                                             |
//! |-------------------|--------------------------------------------------|
//! | Macro-Pass        | expression desugaring/typing in [`crate::expr`] + [`domain::fold_expressions`] |
//! | Domain-Pass       | [`domain`]: normalization, filter fusion, constant folding |
//! | DataFrame-Pass    | [`dataframe`]: predicate pushdown through join, column pruning |
//! | (physical planning) | [`skew`]: skew-aware join strategy selection from source stats |
//! | Distributed-Pass  | [`distributed`]: distribution inference + rebalance insertion |
//! | CGen              | [`crate::exec`]: lowering to the SPMD physical interpreter |
//!
//! Every transformation is toggleable through [`PassOptions`] so the
//! ablation benches can quantify each one (DESIGN.md §5).

pub mod dataframe;
pub mod distributed;
pub mod domain;
pub mod reorder;
pub mod skew;

use crate::ir::graph::PlanGraph;
use crate::ir::Plan;
use anyhow::Result;

/// Rebalance-insertion policy (paper §4.4 discusses exactly this choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalanceMode {
    /// Insert only where a consumer requires `1D_BLOCK` (the paper's novel
    /// `1D_VAR` approach — "rebalance only when necessary").
    Lazy,
    /// Rebalance after *every* relational operation ("one could rebalance
    /// the data frames after every relational operation but this can be
    /// very costly") — the ablation baseline.
    Always,
}

/// Optimization toggles.
#[derive(Debug, Clone)]
pub struct PassOptions {
    pub fold_constants: bool,
    pub fuse_filters: bool,
    pub pushdown: bool,
    pub prune_columns: bool,
    /// Auto-select the skew-aware broadcast join where source statistics
    /// show heavy-hitter probe keys ([`skew::select_skew_joins`]).
    pub skew_join: bool,
    /// Reorder inner-join chains by estimated build-side cost
    /// ([`reorder::reorder_joins_graph`]). Off by default: the rewrite
    /// preserves the result as a multiset but not its engine-defined row
    /// order, so it is opt-in like in most engines' early releases.
    pub join_reorder: bool,
    /// Hash-cons identical subplans into one graph node, materialized once
    /// per rank ([`PlanGraph::from_plan`]). On by default; `none()` turns
    /// it off so the unoptimized configuration executes the exact tree.
    pub dedup_subplans: bool,
    pub rebalance: RebalanceMode,
}

impl Default for PassOptions {
    fn default() -> Self {
        PassOptions {
            fold_constants: true,
            fuse_filters: true,
            pushdown: true,
            prune_columns: true,
            skew_join: true,
            join_reorder: false,
            dedup_subplans: true,
            rebalance: RebalanceMode::Lazy,
        }
    }
}

impl PassOptions {
    /// Everything off — the "unoptimized" configuration for ablations.
    pub fn none() -> PassOptions {
        PassOptions {
            fold_constants: false,
            fuse_filters: false,
            pushdown: false,
            prune_columns: false,
            skew_join: false,
            join_reorder: false,
            dedup_subplans: false,
            rebalance: RebalanceMode::Lazy,
        }
    }
}

/// Run the full pipeline over a logical plan, returning the optimized
/// graph (the form the executor walks and `explain` renders).
pub fn optimize_graph(plan: Plan, opts: &PassOptions) -> Result<PlanGraph> {
    // type-check the incoming plan first: passes assume a well-typed plan
    plan.schema()?;
    let mut g = PlanGraph::from_plan(&plan, opts.dedup_subplans);
    if opts.fold_constants {
        g = domain::fold_expressions_graph(&g);
    }
    if opts.fuse_filters {
        g = domain::fuse_filters_graph(&g);
    }
    if opts.pushdown {
        g = dataframe::pushdown_graph(&g);
        if opts.fuse_filters {
            // pushdown can stack filters on one input; re-fuse
            g = domain::fuse_filters_graph(&g);
        }
    }
    if opts.prune_columns {
        g = dataframe::prune_graph(&g)?;
    }
    if opts.join_reorder {
        // before strategy selection: the skew flip depends on which side
        // ends up as the probe
        g = reorder::reorder_joins_graph(&g);
    }
    if opts.skew_join {
        // after pushdown/pruning so the walk to the source sees the final
        // chain; the runtime sampling pass re-detects the heavy set anyway
        g = skew::select_skew_joins_graph(&g);
    }
    g = distributed::insert_rebalances_graph(&g, opts.rebalance);
    // the optimized plan must still type-check — cheap invariant guard
    g.schema()?;
    Ok(g)
}

/// Run the full pipeline over a logical plan (tree entry point — shared
/// subplans are re-expanded on the way out).
pub fn optimize(plan: Plan, opts: &PassOptions) -> Result<Plan> {
    Ok(optimize_graph(plan, opts)?.to_plan())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit};
    use crate::ir::source_mem;
    use crate::table::Table;

    fn src() -> Plan {
        source_mem(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2, 3])),
                ("x", Column::F64(vec![0.1, 0.2, 0.3])),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn optimize_preserves_schema() {
        let plan = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(1.0).add(lit(1.0))),
        };
        let before = plan.schema().unwrap();
        let opt = optimize(plan, &PassOptions::default()).unwrap();
        assert!(before.same_as(&opt.schema().unwrap()));
    }

    #[test]
    fn optimize_rejects_ill_typed() {
        let plan = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").add(lit(1.0)), // not Bool
        };
        assert!(optimize(plan, &PassOptions::default()).is_err());
    }

    #[test]
    fn options_none_is_identityish() {
        let plan = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(2.0)),
        };
        let opt = optimize(plan.clone(), &PassOptions::none()).unwrap();
        assert_eq!(opt.size(), plan.size());
    }
}
