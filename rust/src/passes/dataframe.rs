//! DataFrame-Pass (paper §4.3): relational optimizations over the general
//! program IR.
//!
//! The paper builds a query tree of *only* the relational nodes, checks
//! rewrite rules, and validates each candidate against the whole program
//! with liveness analysis (array code may use a column between two
//! relational operators). In our tree IR the intervening non-relational
//! nodes are explicit ([`Plan::WithColumn`], [`Plan::Rename`], …), so the
//! liveness check becomes a syntactic guard: a predicate may move past a
//! node only if the columns it reads are untouched by that node.
//!
//! Implemented rewrites:
//! * **push predicate through join** — the paper's flagship rule (Fig. 6),
//!   generalized to composite keys and join types: the predicate is split
//!   into conjuncts and only the conjuncts that survive the join type move.
//!   A conjunct over one side is *null-sensitive* when that side can be
//!   null-introduced (Left join → right side, Right join → left side, Outer
//!   → both): unmatched rows carry cleared validity bits post-join, where
//!   ordinary comparisons evaluate to NULL (dropped by the filter) and
//!   `IS NULL` evaluates to true — pre-join filtering sees neither, so
//!   those conjuncts must stay above the join.
//! * **push predicate through with-column / rename / project** — the
//!   "liveness" plumbing that lets predicates travel past array code.
//! * **column pruning** — dead-column elimination with whole-program
//!   knowledge ("ParallelAccelerator dead code elimination will remove
//!   unused columns … while Spark SQL performs column pruning only within
//!   the SQL context"), over key *sets* for joins/aggregates/sorts.

use crate::expr::{AggExpr, Expr};
use crate::fxhash::FxHashMap;
use crate::ir::graph::{Node, NodeId, PlanGraph, Store};
use crate::ir::{JoinType, Plan, WindowAgg};
use crate::table::Schema;
use anyhow::Result;
use std::collections::BTreeSet;

/// Apply predicate pushdown rules to fixpoint (tree entry point — a thin
/// round trip through [`pushdown_graph`]).
pub fn pushdown_predicates(plan: Plan) -> Plan {
    pushdown_graph(&PlanGraph::from_plan(&plan, false)).to_plan()
}

/// Graph form of predicate pushdown, run to fixpoint. Each successful
/// rewrite strictly moves a Filter toward the leaves, so `node_count()`
/// sweeps bound the fixpoint; the canonical positional rendering makes
/// the no-change check exact even as arena ids shift between sweeps.
pub fn pushdown_graph(g: &PlanGraph) -> PlanGraph {
    let mut cur = g.clone();
    for _ in 0..cur.node_count() {
        let before = cur.render(false);
        let next = cur.rewrite(push_one_rule);
        let stable = next.render(false) == before;
        cur = next;
        if stable {
            break;
        }
    }
    cur
}

/// Flatten nested `And`s into a conjunct list.
fn split_conjuncts(e: Expr, out: &mut Vec<Expr>) {
    match e {
        Expr::And(a, b) => {
            split_conjuncts(*a, out);
            split_conjuncts(*b, out);
        }
        other => out.push(other),
    }
}

/// Rebuild a predicate from conjuncts (left-folded `And` chain).
fn and_all(mut conjs: Vec<Expr>) -> Expr {
    let first = conjs.remove(0);
    conjs.into_iter().fold(first, |acc, c| acc.and(c))
}

/// One local pushdown step on a node (children already rewritten; new
/// interior nodes are interned by the rule, the returned node by the
/// rewrite driver).
fn push_one_rule(st: &mut Store, node: Node) -> Node {
    let Node::Filter { input, predicate } = node else {
        return node;
    };
    match st.node(input).clone() {
        // ---- the paper's rule: Filter(Join) → Join(Filter, ·),
        // ---- generalized to join types via per-conjunct analysis --------
        Node::Join {
            left,
            right,
            on,
            how,
            strategy,
        } => {
            let lnames: BTreeSet<String> = st
                .schema_of(left)
                .map(|s| s.names().iter().map(|n| n.to_string()).collect())
                .unwrap_or_default();
            let rnames: BTreeSet<String> = st
                .schema_of(right)
                .map(|s| s.names().iter().map(|n| n.to_string()).collect())
                .unwrap_or_default();
            // which sides accept pre-join filtering without changing the
            // result? a side is off-limits once it can be null-introduced
            // (its conjuncts are null-sensitive) or — for right pushes —
            // when unmatched right rows must keep contributing (Left/Outer).
            let can_left = matches!(
                how,
                JoinType::Inner | JoinType::Left | JoinType::Semi | JoinType::Anti
            );
            let can_right = matches!(how, JoinType::Inner | JoinType::Right);
            let mut conjs = Vec::new();
            split_conjuncts(predicate.clone(), &mut conjs);
            let mut push_left = Vec::new();
            let mut push_right = Vec::new();
            let mut stay = Vec::new();
            for c in conjs {
                let used = c.columns_used();
                if used.is_empty() {
                    stay.push(c);
                    continue;
                }
                if can_left && used.is_subset(&lnames) {
                    // filter the left input instead (Fig. 6's transformation)
                    push_left.push(c);
                    continue;
                }
                if can_right {
                    // in the output the join keys are named by their *left*
                    // key; map them back to the right names before pushing
                    let renamed = c.rename_columns(&|col| {
                        if let Some((_, rk)) = on.iter().find(|(lk, _)| lk == col) {
                            Some(rk.clone())
                        } else if rnames.contains(col) && !lnames.contains(col) {
                            Some(col.to_string())
                        } else {
                            None
                        }
                    });
                    if let Some(rpred) = renamed {
                        push_right.push(rpred);
                        continue;
                    }
                }
                stay.push(c);
            }
            if push_left.is_empty() && push_right.is_empty() {
                // nothing moves: keep the original predicate verbatim so the
                // fixpoint loop's plan-text comparison stabilizes
                return Node::Filter { input, predicate };
            }
            let left = if push_left.is_empty() {
                left
            } else {
                st.intern(Node::Filter {
                    input: left,
                    predicate: and_all(push_left),
                })
            };
            let right = if push_right.is_empty() {
                right
            } else {
                st.intern(Node::Filter {
                    input: right,
                    predicate: and_all(push_right),
                })
            };
            let join = Node::Join {
                left,
                right,
                on,
                how,
                strategy,
            };
            if stay.is_empty() {
                join
            } else {
                let join = st.intern(join);
                Node::Filter {
                    input: join,
                    predicate: and_all(stay),
                }
            }
        }
        // ---- liveness plumbing: move past array code it doesn't read ----
        Node::WithColumn {
            input: wc_input,
            name,
            expr,
        } => {
            if predicate.columns_used().contains(&name) {
                // predicate reads the computed column: blocked (the paper's
                // "transformation could change the result" case)
                Node::Filter { input, predicate }
            } else {
                let filtered = st.intern(Node::Filter {
                    input: wc_input,
                    predicate,
                });
                Node::WithColumn {
                    input: filtered,
                    name,
                    expr,
                }
            }
        }
        Node::Rename {
            input: rn_input,
            from,
            to,
        } => {
            let renamed = predicate.rename_columns(&|c| {
                if c == to {
                    Some(from.clone())
                } else {
                    Some(c.to_string())
                }
            });
            match renamed {
                Some(rpred) => {
                    let filtered = st.intern(Node::Filter {
                        input: rn_input,
                        predicate: rpred,
                    });
                    Node::Rename {
                        input: filtered,
                        from,
                        to,
                    }
                }
                None => Node::Filter { input, predicate },
            }
        }
        Node::Project {
            input: pj_input,
            columns,
        } => {
            let filtered = st.intern(Node::Filter {
                input: pj_input,
                predicate,
            });
            Node::Project {
                input: filtered,
                columns,
            }
        }
        // concat distributes the filter into every branch
        Node::Concat { inputs } => Node::Concat {
            inputs: inputs
                .into_iter()
                .map(|p| {
                    st.intern(Node::Filter {
                        input: p,
                        predicate: predicate.clone(),
                    })
                })
                .collect(),
        },
        // Cache is a deliberate barrier: the user pinned that exact
        // subplan, so the filter stays above it (like any opaque node)
        _ => Node::Filter { input, predicate },
    }
}

/// Column pruning (tree entry point — a thin round trip through
/// [`prune_graph`]).
pub fn prune_columns(plan: Plan) -> Result<Plan> {
    Ok(prune_graph(&PlanGraph::from_plan(&plan, false))?.to_plan())
}

/// Graph column pruning: compute the set of columns each node's consumers
/// need (union over all consumers — a shared node keeps any column *some*
/// consumer reads), then rebuild bottom-up, dropping dead
/// [`Node::WithColumn`]s / dead global windows and inserting projections
/// over sources so ranks never materialize unused columns.
pub fn prune_graph(g: &PlanGraph) -> Result<PlanGraph> {
    let schemas = g.schemas()?;
    // ---- phase 1: needed sets, consumers before producers ----------------
    let mut needed: FxHashMap<NodeId, BTreeSet<String>> = FxHashMap::default();
    needed.insert(
        g.completion,
        schemas[&g.completion]
            .names()
            .iter()
            .map(|n| n.to_string())
            .collect(),
    );
    for &id in g.execution_order.iter().rev() {
        let need = needed.entry(id).or_default().clone();
        for (child, n) in child_needs(&g.store[id], &need, &schemas) {
            needed.entry(child).or_default().extend(n);
        }
    }
    // ---- phase 2: bottom-up rebuild with the final needed sets -----------
    let mut out = Store::like(&g.store);
    let mut map: FxHashMap<NodeId, NodeId> = FxHashMap::default();
    for &id in &g.execution_order {
        let need = &needed[&id];
        let new_id = match g.store[id].clone().remap(&map) {
            Node::Source { name, src, schema } => {
                let keep: Vec<String> = schema
                    .names()
                    .iter()
                    .filter(|n| need.contains(**n))
                    .map(|n| n.to_string())
                    .collect();
                let wrap = keep.len() < schema.len() && !keep.is_empty();
                let src_id = out.intern(Node::Source { name, src, schema });
                if wrap {
                    out.intern(Node::Project {
                        input: src_id,
                        columns: keep,
                    })
                } else {
                    src_id
                }
            }
            Node::Project { input, columns } => {
                let keep: Vec<String> = columns
                    .iter()
                    .filter(|c| need.contains(*c))
                    .cloned()
                    .collect();
                let keep = if keep.is_empty() { columns } else { keep };
                out.intern(Node::Project {
                    input,
                    columns: keep,
                })
            }
            Node::WithColumn { input, name, expr } => {
                if !need.contains(&name) {
                    // dead column computation — alias to the pruned child
                    input
                } else {
                    out.intern(Node::WithColumn { input, name, expr })
                }
            }
            Node::Aggregate { input, keys, aggs } => {
                let aggs = kept_agg_exprs(&aggs, need);
                out.intern(Node::Aggregate { input, keys, aggs })
            }
            Node::Window {
                input,
                partition_by,
                order_by,
                aggs,
            } => {
                // a *global* window whose outputs are all dead is the
                // identity on the surviving columns; a partitioned window
                // also reorders rows, so it must stay even when its outputs
                // are unused
                if partition_by.is_empty() && aggs.iter().all(|a| !need.contains(&a.out)) {
                    input
                } else {
                    let aggs = kept_window_aggs(&aggs, need);
                    out.intern(Node::Window {
                        input,
                        partition_by,
                        order_by,
                        aggs,
                    })
                }
            }
            other => out.intern(other),
        };
        map.insert(id, new_id);
    }
    Ok(PlanGraph::new(out, map[&g.completion]))
}

/// Aggregates whose output some consumer needs (all kept when none are —
/// an aggregate must produce at least one column).
fn kept_agg_exprs(aggs: &[AggExpr], needed: &BTreeSet<String>) -> Vec<AggExpr> {
    let kept: Vec<AggExpr> = aggs
        .iter()
        .filter(|a| needed.contains(&a.out))
        .cloned()
        .collect();
    if kept.is_empty() {
        aggs.to_vec()
    } else {
        kept
    }
}

fn kept_window_aggs(aggs: &[WindowAgg], needed: &BTreeSet<String>) -> Vec<WindowAgg> {
    let kept: Vec<WindowAgg> = aggs
        .iter()
        .filter(|a| needed.contains(&a.out))
        .cloned()
        .collect();
    if kept.is_empty() {
        aggs.to_vec()
    } else {
        kept
    }
}

/// What `node` demands of each child, given what its own consumers need.
/// Mirrors the per-operator rules of the original top-down tree prune.
fn child_needs(
    node: &Node,
    needed: &BTreeSet<String>,
    schemas: &FxHashMap<NodeId, Schema>,
) -> Vec<(NodeId, BTreeSet<String>)> {
    match node {
        Node::Source { .. } => vec![],
        Node::Filter { input, predicate } => {
            let mut n = needed.clone();
            n.extend(predicate.columns_used());
            vec![(*input, n)]
        }
        Node::Project { input, columns } => {
            let keep: Vec<String> = columns
                .iter()
                .filter(|c| needed.contains(*c))
                .cloned()
                .collect();
            let keep = if keep.is_empty() { columns.clone() } else { keep };
            vec![(*input, keep.into_iter().collect())]
        }
        Node::WithColumn { input, name, expr } => {
            if !needed.contains(name) {
                vec![(*input, needed.clone())]
            } else {
                let mut n: BTreeSet<String> =
                    needed.iter().filter(|c| **c != *name).cloned().collect();
                n.extend(expr.columns_used());
                vec![(*input, n)]
            }
        }
        Node::Rename { input, from, to } => {
            let mut n: BTreeSet<String> = needed
                .iter()
                .map(|c| if c == to { from.clone() } else { c.clone() })
                .collect();
            // keep `from` alive even if output name unused downstream
            n.insert(from.clone());
            vec![(*input, n)]
        }
        Node::Join {
            left,
            right,
            on,
            how,
            ..
        } => {
            let lnames: BTreeSet<String> = schemas[left]
                .names()
                .iter()
                .map(|n| n.to_string())
                .collect();
            let rnames: BTreeSet<String> = schemas[right]
                .names()
                .iter()
                .map(|n| n.to_string())
                .collect();
            let mut ln: BTreeSet<String> = needed.intersection(&lnames).cloned().collect();
            // a Semi/Anti join only reads the right side's key columns, so
            // everything else on the right is prunable regardless of `needed`
            let mut rn: BTreeSet<String> = if how.keeps_right_columns() {
                needed.intersection(&rnames).cloned().collect()
            } else {
                BTreeSet::new()
            };
            for (lk, rk) in on {
                ln.insert(lk.clone());
                rn.insert(rk.clone());
            }
            vec![(*left, ln), (*right, rn)]
        }
        Node::Aggregate { input, keys, aggs } => {
            let aggs = kept_agg_exprs(aggs, needed);
            let mut n = BTreeSet::new();
            for key in keys {
                n.insert(key.clone());
            }
            for a in &aggs {
                n.extend(a.input.columns_used());
            }
            vec![(*input, n)]
        }
        // all branches must keep identical schemas: each gets the same set
        Node::Concat { inputs } => inputs.iter().map(|i| (*i, needed.clone())).collect(),
        Node::Window {
            input,
            partition_by,
            order_by,
            aggs,
        } => {
            if partition_by.is_empty() && aggs.iter().all(|a| !needed.contains(&a.out)) {
                return vec![(*input, needed.clone())];
            }
            let aggs = kept_window_aggs(aggs, needed);
            let mut n: BTreeSet<String> = needed
                .iter()
                .filter(|c| !aggs.iter().any(|a| &a.out == *c))
                .cloned()
                .collect();
            for key in partition_by {
                n.insert(key.clone());
            }
            for (key, _) in order_by {
                n.insert(key.clone());
            }
            for a in &aggs {
                n.extend(a.input.columns_used());
            }
            vec![(*input, n)]
        }
        Node::Sort { input, keys } => {
            let mut n = needed.clone();
            for (key, _) in keys {
                n.insert(key.clone());
            }
            vec![(*input, n)]
        }
        Node::Rebalance { input } => vec![(*input, needed.clone())],
        Node::MatrixAssembly { input, columns } => {
            vec![(*input, columns.iter().cloned().collect())]
        }
        // Cache pins the *whole* subplan result (the cached table is shared
        // across queries with different needs), MlCall reads every column —
        // both demand the full child schema
        Node::MlCall { input, .. } | Node::Cache { input } => {
            let n: BTreeSet<String> = schemas[input]
                .names()
                .iter()
                .map(|s| s.to_string())
                .collect();
            vec![(*input, n)]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit, AggExpr, AggFn};
    use crate::ir::source_mem;
    use crate::table::Table;

    fn customer() -> Plan {
        source_mem(
            "customer",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2])),
                ("phone", Column::I64(vec![555, 666])),
            ])
            .unwrap(),
        )
    }

    fn orders() -> Plan {
        source_mem(
            "order",
            Table::from_pairs(vec![
                ("customerId", Column::I64(vec![1, 2])),
                ("amount", Column::F64(vec![50.0, 150.0])),
            ])
            .unwrap(),
        )
    }

    fn join_of(how: crate::ir::JoinType) -> Plan {
        Plan::Join {
            left: Box::new(customer()),
            right: Box::new(orders()),
            on: vec![("id".into(), "customerId".into())],
            how,
            strategy: crate::ir::JoinStrategy::Hash,
        }
    }

    /// The paper's Fig. 6 example, verbatim.
    #[test]
    fn pushes_right_side_predicate_through_join() {
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Inner)),
            predicate: col("amount").gt(lit(100.0)),
        };
        let opt = pushdown_predicates(plan);
        // expect Join(customer, Filter(order))
        match &opt {
            Plan::Join { left, right, .. } => {
                assert!(matches!(**left, Plan::Source { .. }));
                assert!(matches!(**right, Plan::Filter { .. }));
            }
            other => panic!("expected join at root, got:\n{other}"),
        }
        assert!(opt.schema().is_ok());
    }

    #[test]
    fn pushes_left_side_predicate_through_join() {
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Inner)),
            predicate: col("phone").eq_(lit(555i64)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Join { left, right, .. } => {
                assert!(matches!(**left, Plan::Filter { .. }));
                assert!(matches!(**right, Plan::Source { .. }));
            }
            other => panic!("expected join at root, got:\n{other}"),
        }
    }

    #[test]
    fn key_predicate_pushes_with_rename() {
        // :id is the output name of the join key; pushing right requires
        // renaming it back to :customerId
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Inner)),
            predicate: col("id").lt(lit(2i64)),
        };
        let opt = pushdown_predicates(plan);
        // :id exists on the left, so it pushes left (left precedence)
        match &opt {
            Plan::Join { left, .. } => assert!(matches!(**left, Plan::Filter { .. })),
            other => panic!("expected join at root, got:\n{other}"),
        }
    }

    #[test]
    fn mixed_predicate_stays_above_join() {
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Inner)),
            predicate: col("phone").lt(col("amount")), // reads both sides
        };
        let opt = pushdown_predicates(plan.clone());
        match &opt {
            Plan::Filter { input, .. } => assert!(matches!(**input, Plan::Join { .. })),
            other => panic!("expected filter to stay, got:\n{other}"),
        }
    }

    #[test]
    fn conjuncts_split_across_join_sides() {
        // (phone == 555) && (amount > 100): one conjunct per side, both push
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Inner)),
            predicate: col("phone")
                .eq_(lit(555i64))
                .and(col("amount").gt(lit(100.0))),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Join { left, right, .. } => {
                assert!(matches!(**left, Plan::Filter { .. }));
                assert!(matches!(**right, Plan::Filter { .. }));
            }
            other => panic!("expected join at root, got:\n{other}"),
        }
    }

    #[test]
    fn left_join_blocks_null_sensitive_right_conjunct() {
        // amount > 100 over a LEFT join is null-sensitive: unmatched
        // customers have a null amount post-join (cleared validity bit →
        // the comparison is NULL → the filter drops the row), which a
        // pre-join push of the conjunct would not reproduce.
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Left)),
            predicate: col("amount").gt(lit(100.0)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Filter { input, .. } => match &**input {
                Plan::Join { right, .. } => {
                    assert!(matches!(**right, Plan::Source { .. }), "right was filtered");
                }
                other => panic!("expected join under filter, got:\n{other}"),
            },
            other => panic!("expected filter to stay above left join, got:\n{other}"),
        }
    }

    #[test]
    fn left_join_blocks_is_null_probe_on_right_side() {
        // the Q05 migration shape: IS NULL over the null-introduced side
        // selects exactly the unmatched rows — it must never push below the
        // join (pre-join, no right row is null)
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Left)),
            predicate: col("amount").is_null(),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Filter { input, predicate } => {
                assert_eq!(*predicate, col("amount").is_null());
                assert!(matches!(**input, Plan::Join { .. }));
            }
            other => panic!("expected IS NULL to stay above left join, got:\n{other}"),
        }
        // IS NOT NULL (the drop_null desugaring) stays put too
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Left)),
            predicate: col("amount").is_not_null(),
        };
        let opt = pushdown_predicates(plan);
        assert!(
            matches!(&opt, Plan::Filter { input, .. } if matches!(&**input, Plan::Join { .. })),
            "got:\n{opt}"
        );
        // …while over an INNER join the probe pushes into the right input
        // (an inner join introduces no nulls, so the rewrite is sound)
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Inner)),
            predicate: col("amount").is_not_null(),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Join { right, .. } => {
                assert!(matches!(**right, Plan::Filter { .. }))
            }
            other => panic!("expected pushdown through inner join, got:\n{other}"),
        }
    }

    #[test]
    fn left_join_still_pushes_left_conjunct() {
        // a left-side conjunct commutes with a LEFT join: each surviving
        // left row's value is unchanged by the join
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Left)),
            predicate: col("phone")
                .eq_(lit(555i64))
                .and(col("amount").gt(lit(100.0))),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Filter { input, predicate } => {
                // the null-sensitive amount conjunct stays…
                assert!(predicate.columns_used().contains("amount"));
                assert!(!predicate.columns_used().contains("phone"));
                // …while the phone conjunct moved into the left input
                match &**input {
                    Plan::Join { left, .. } => {
                        assert!(matches!(**left, Plan::Filter { .. }))
                    }
                    other => panic!("expected join, got:\n{other}"),
                }
            }
            other => panic!("expected partial pushdown, got:\n{other}"),
        }
    }

    #[test]
    fn right_join_blocks_left_conjunct_pushes_right() {
        // mirror image: RIGHT join nulls the left side
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Right)),
            predicate: col("phone")
                .eq_(lit(555i64))
                .and(col("amount").gt(lit(100.0))),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Filter { input, predicate } => {
                assert!(predicate.columns_used().contains("phone"));
                match &**input {
                    Plan::Join { left, right, .. } => {
                        assert!(matches!(**left, Plan::Source { .. }));
                        assert!(matches!(**right, Plan::Filter { .. }));
                    }
                    other => panic!("expected join, got:\n{other}"),
                }
            }
            other => panic!("expected partial pushdown, got:\n{other}"),
        }
    }

    #[test]
    fn outer_join_blocks_all_side_conjuncts() {
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Outer)),
            predicate: col("phone")
                .eq_(lit(555i64))
                .and(col("amount").gt(lit(100.0))),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Filter { input, .. } => match &**input {
                Plan::Join { left, right, .. } => {
                    assert!(matches!(**left, Plan::Source { .. }));
                    assert!(matches!(**right, Plan::Source { .. }));
                }
                other => panic!("expected pristine join, got:\n{other}"),
            },
            other => panic!("expected filter to stay above outer join, got:\n{other}"),
        }
    }

    #[test]
    fn semi_join_pushes_left_conjunct() {
        let plan = Plan::Filter {
            input: Box::new(join_of(JoinType::Semi)),
            predicate: col("phone").eq_(lit(555i64)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Join { left, how, .. } => {
                assert_eq!(*how, JoinType::Semi);
                assert!(matches!(**left, Plan::Filter { .. }));
            }
            other => panic!("expected semi join at root, got:\n{other}"),
        }
    }

    #[test]
    fn filter_moves_past_unrelated_withcolumn() {
        // the paper's liveness case: array computation between relational ops
        let plan = Plan::Filter {
            input: Box::new(Plan::WithColumn {
                input: Box::new(orders()),
                name: "scaled".into(),
                expr: col("amount").mul(lit(2.0)),
            }),
            predicate: col("customerId").lt(lit(10i64)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::WithColumn { input, .. } => {
                assert!(matches!(**input, Plan::Filter { .. }));
            }
            other => panic!("expected WithColumn at root, got:\n{other}"),
        }
    }

    #[test]
    fn filter_blocked_by_dependent_withcolumn() {
        let plan = Plan::Filter {
            input: Box::new(Plan::WithColumn {
                input: Box::new(orders()),
                name: "scaled".into(),
                expr: col("amount").mul(lit(2.0)),
            }),
            predicate: col("scaled").gt(lit(100.0)), // reads the new column
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Filter { input, .. } => {
                assert!(matches!(**input, Plan::WithColumn { .. }));
            }
            other => panic!("expected blocked filter, got:\n{other}"),
        }
    }

    #[test]
    fn filter_distributes_into_concat() {
        let plan = Plan::Filter {
            input: Box::new(Plan::Concat {
                inputs: vec![Box::new(orders()), Box::new(orders())],
            }),
            predicate: col("amount").gt(lit(100.0)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Concat { inputs } => {
                for p in inputs {
                    assert!(matches!(**p, Plan::Filter { .. }));
                }
            }
            other => panic!("expected concat at root, got:\n{other}"),
        }
    }

    #[test]
    fn prune_inserts_projection_over_source() {
        // only :amount survives to the root → :customerId must still be
        // read (join key), :phone must be pruned from customer
        let plan = Plan::Project {
            input: Box::new(join_of(JoinType::Inner)),
            columns: vec!["amount".into()],
        };
        let opt = prune_columns(plan).unwrap();
        let txt = format!("{opt}");
        // customer source must now be wrapped in Project(id) — no :phone
        assert!(txt.contains("Project(id)"), "plan:\n{txt}");
        assert!(opt.schema().unwrap().names() == vec!["amount"]);
    }

    #[test]
    fn prune_semi_join_right_to_keys_only() {
        // a Semi join reads nothing but the right key column, whatever the
        // consumer needs
        let plan = join_of(JoinType::Semi);
        let opt = prune_columns(plan).unwrap();
        let txt = format!("{opt}");
        assert!(txt.contains("Project(customerId)"), "plan:\n{txt}");
        assert_eq!(opt.schema().unwrap().names(), vec!["id", "phone"]);
    }

    #[test]
    fn prune_drops_dead_withcolumn() {
        let plan = Plan::Project {
            input: Box::new(Plan::WithColumn {
                input: Box::new(orders()),
                name: "dead".into(),
                expr: col("amount").mul(lit(0.5)),
            }),
            columns: vec!["amount".into()],
        };
        let opt = prune_columns(plan).unwrap();
        assert!(!format!("{opt}").contains("dead"), "plan:\n{opt}");
    }

    #[test]
    fn prune_keeps_agg_inputs() {
        let plan = Plan::Aggregate {
            input: Box::new(orders()),
            keys: vec!["customerId".into()],
            aggs: vec![AggExpr::new("total", AggFn::Sum, col("amount"))],
        };
        let opt = prune_columns(plan).unwrap();
        assert_eq!(opt.schema().unwrap().names(), vec!["customerId", "total"]);
    }

    #[test]
    fn prune_window_keeps_keys_and_inputs_drops_dead_global() {
        use crate::ir::{WindowAgg, WindowFrame, WindowFunc};
        let wide = || {
            source_mem(
                "wide",
                Table::from_pairs(vec![
                    ("k", Column::I64(vec![1, 2])),
                    ("o", Column::I64(vec![7, 8])),
                    ("x", Column::F64(vec![0.5, 1.5])),
                    ("unused", Column::F64(vec![9.0, 9.0])),
                ])
                .unwrap(),
            )
        };
        // partitioned window: partition/order keys and agg inputs survive
        // the projection inserted over the source; :unused does not
        let plan = Plan::Project {
            input: Box::new(Plan::Window {
                input: Box::new(wide()),
                partition_by: vec!["k".into()],
                order_by: vec![("o".into(), crate::ir::SortOrder::Asc)],
                aggs: vec![WindowAgg::new(
                    "cs",
                    WindowFunc::Sum,
                    WindowFrame::CumulativeToCurrent,
                    col("x"),
                )],
            }),
            columns: vec!["cs".into()],
        };
        let opt = prune_columns(plan).unwrap();
        let txt = format!("{opt}");
        assert!(txt.contains("Project(k, o, x)"), "plan:\n{txt}");
        // a dead *global* window is the identity — eliminated entirely
        let plan = Plan::Project {
            input: Box::new(Plan::Window {
                input: Box::new(wide()),
                partition_by: vec![],
                order_by: vec![],
                aggs: vec![WindowAgg::new(
                    "cs",
                    WindowFunc::Sum,
                    WindowFrame::CumulativeToCurrent,
                    col("x"),
                )],
            }),
            columns: vec!["k".into()],
        };
        let opt = prune_columns(plan).unwrap();
        assert!(!format!("{opt}").contains("Window"), "plan:\n{opt}");
    }

    #[test]
    fn prune_keeps_all_keys_of_multi_key_aggregate() {
        // wide source; aggregate by (id, phone) — both keys must survive the
        // projection inserted over the source
        let wide = source_mem(
            "wide",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2])),
                ("phone", Column::I64(vec![555, 666])),
                ("x", Column::F64(vec![0.5, 1.5])),
                ("unused", Column::F64(vec![9.0, 9.0])),
            ])
            .unwrap(),
        );
        let plan = Plan::Aggregate {
            input: Box::new(wide),
            keys: vec!["id".into(), "phone".into()],
            aggs: vec![AggExpr::new("s", AggFn::Sum, col("x"))],
        };
        let opt = prune_columns(plan).unwrap();
        let txt = format!("{opt}");
        assert!(txt.contains("Project(id, phone, x)"), "plan:\n{txt}");
        assert_eq!(opt.schema().unwrap().names(), vec!["id", "phone", "s"]);
    }
}
