//! DataFrame-Pass (paper §4.3): relational optimizations over the general
//! program IR.
//!
//! The paper builds a query tree of *only* the relational nodes, checks
//! rewrite rules, and validates each candidate against the whole program
//! with liveness analysis (array code may use a column between two
//! relational operators). In our tree IR the intervening non-relational
//! nodes are explicit ([`Plan::WithColumn`], [`Plan::Rename`], …), so the
//! liveness check becomes a syntactic guard: a predicate may move past a
//! node only if the columns it reads are untouched by that node.
//!
//! Implemented rewrites:
//! * **push predicate through join** — the paper's flagship rule (Fig. 6).
//! * **push predicate through with-column / rename / project** — the
//!   "liveness" plumbing that lets predicates travel past array code.
//! * **column pruning** — dead-column elimination with whole-program
//!   knowledge ("ParallelAccelerator dead code elimination will remove
//!   unused columns … while Spark SQL performs column pruning only within
//!   the SQL context").

use super::domain::map_plan;
use crate::ir::Plan;
use anyhow::Result;
use std::collections::BTreeSet;

/// Apply predicate pushdown rules to fixpoint (bounded by plan size).
pub fn pushdown_predicates(plan: Plan) -> Plan {
    let mut p = plan;
    // each successful rewrite strictly moves a Filter toward the leaves, so
    // size() iterations are enough for a fixpoint
    for _ in 0..p.size() {
        let before = format!("{p}");
        p = map_plan(p, &push_one);
        if format!("{p}") == before {
            break;
        }
    }
    p
}

/// One local pushdown step on a node (children already rewritten).
fn push_one(node: Plan) -> Plan {
    let Plan::Filter { input, predicate } = node else {
        return node;
    };
    match *input {
        // ---- the paper's rule: Filter(Join) → Join(Filter, ·) ----------
        Plan::Join {
            left,
            right,
            left_key,
            right_key,
        } => {
            let used = predicate.columns_used();
            let lnames: BTreeSet<String> = left
                .schema()
                .map(|s| s.names().iter().map(|n| n.to_string()).collect())
                .unwrap_or_default();
            let rnames: BTreeSet<String> = right
                .schema()
                .map(|s| s.names().iter().map(|n| n.to_string()).collect())
                .unwrap_or_default();
            if !used.is_empty() && used.is_subset(&lnames) {
                // filter the left input instead (Fig. 6's transformation)
                Plan::Join {
                    left: Box::new(Plan::Filter {
                        input: left,
                        predicate,
                    }),
                    right,
                    left_key,
                    right_key,
                }
            } else {
                // on the right side the join key is named `left_key` in the
                // output; map it back to `right_key` before pushing
                let renamed = predicate.rename_columns(&|c| {
                    if c == left_key {
                        Some(right_key.clone())
                    } else if rnames.contains(c) && !lnames.contains(c) {
                        Some(c.to_string())
                    } else {
                        None
                    }
                });
                match renamed {
                    Some(rpred) if !used.is_empty() => Plan::Join {
                        left,
                        right: Box::new(Plan::Filter {
                            input: right,
                            predicate: rpred,
                        }),
                        left_key,
                        right_key,
                    },
                    _ => Plan::Filter {
                        input: Box::new(Plan::Join {
                            left,
                            right,
                            left_key,
                            right_key,
                        }),
                        predicate,
                    },
                }
            }
        }
        // ---- liveness plumbing: move past array code it doesn't read ----
        Plan::WithColumn {
            input: wc_input,
            name,
            expr,
        } => {
            if predicate.columns_used().contains(&name) {
                // predicate reads the computed column: blocked (the paper's
                // "transformation could change the result" case)
                Plan::Filter {
                    input: Box::new(Plan::WithColumn {
                        input: wc_input,
                        name,
                        expr,
                    }),
                    predicate,
                }
            } else {
                Plan::WithColumn {
                    input: Box::new(Plan::Filter {
                        input: wc_input,
                        predicate,
                    }),
                    name,
                    expr,
                }
            }
        }
        Plan::Rename {
            input: rn_input,
            from,
            to,
        } => {
            let renamed = predicate.rename_columns(&|c| {
                if c == to {
                    Some(from.clone())
                } else {
                    Some(c.to_string())
                }
            });
            match renamed {
                Some(rpred) => Plan::Rename {
                    input: Box::new(Plan::Filter {
                        input: rn_input,
                        predicate: rpred,
                    }),
                    from,
                    to,
                },
                None => Plan::Filter {
                    input: Box::new(Plan::Rename {
                        input: rn_input,
                        from,
                        to,
                    }),
                    predicate,
                },
            }
        }
        Plan::Project {
            input: pj_input,
            columns,
        } => Plan::Project {
            input: Box::new(Plan::Filter {
                input: pj_input,
                predicate,
            }),
            columns,
        },
        // concat distributes the filter into every branch
        Plan::Concat { inputs } => Plan::Concat {
            inputs: inputs
                .into_iter()
                .map(|p| {
                    Box::new(Plan::Filter {
                        input: p,
                        predicate: predicate.clone(),
                    })
                })
                .collect(),
        },
        other => Plan::Filter {
            input: Box::new(other),
            predicate,
        },
    }
}

/// Column pruning: walk top-down with the set of columns each consumer
/// needs; drop dead [`Plan::WithColumn`]s and insert projections over
/// sources so ranks never materialize unused columns.
pub fn prune_columns(plan: Plan) -> Result<Plan> {
    let all: BTreeSet<String> = plan
        .schema()?
        .names()
        .iter()
        .map(|n| n.to_string())
        .collect();
    prune(plan, &all)
}

fn prune(plan: Plan, needed: &BTreeSet<String>) -> Result<Plan> {
    Ok(match plan {
        Plan::Source { name, src, schema } => {
            let keep: Vec<String> = schema
                .names()
                .iter()
                .filter(|n| needed.contains(**n))
                .map(|n| n.to_string())
                .collect();
            let src_node = Plan::Source {
                name,
                src,
                schema: schema.clone(),
            };
            if keep.len() < schema.len() && !keep.is_empty() {
                Plan::Project {
                    input: Box::new(src_node),
                    columns: keep,
                }
            } else {
                src_node
            }
        }
        Plan::Filter { input, predicate } => {
            let mut n = needed.clone();
            n.extend(predicate.columns_used());
            Plan::Filter {
                input: Box::new(prune(*input, &n)?),
                predicate,
            }
        }
        Plan::Project { input, columns } => {
            let keep: Vec<String> = columns
                .iter()
                .filter(|c| needed.contains(*c))
                .cloned()
                .collect();
            let keep = if keep.is_empty() { columns } else { keep };
            let n: BTreeSet<String> = keep.iter().cloned().collect();
            Plan::Project {
                input: Box::new(prune(*input, &n)?),
                columns: keep,
            }
        }
        Plan::WithColumn { input, name, expr } => {
            if !needed.contains(&name) {
                // dead column computation — eliminate entirely
                prune(*input, needed)?
            } else {
                let mut n: BTreeSet<String> =
                    needed.iter().filter(|c| **c != name).cloned().collect();
                n.extend(expr.columns_used());
                Plan::WithColumn {
                    input: Box::new(prune(*input, &n)?),
                    name,
                    expr,
                }
            }
        }
        Plan::Rename { input, from, to } => {
            let mut n: BTreeSet<String> = needed
                .iter()
                .map(|c| if c == &to { from.clone() } else { c.clone() })
                .collect();
            // keep `from` alive even if output name unused downstream
            n.insert(from.clone());
            Plan::Rename {
                input: Box::new(prune(*input, &n)?),
                from,
                to,
            }
        }
        Plan::Join {
            left,
            right,
            left_key,
            right_key,
        } => {
            let lnames: BTreeSet<String> = left
                .schema()?
                .names()
                .iter()
                .map(|n| n.to_string())
                .collect();
            let rnames: BTreeSet<String> = right
                .schema()?
                .names()
                .iter()
                .map(|n| n.to_string())
                .collect();
            let mut ln: BTreeSet<String> =
                needed.intersection(&lnames).cloned().collect();
            ln.insert(left_key.clone());
            let mut rn: BTreeSet<String> =
                needed.intersection(&rnames).cloned().collect();
            rn.insert(right_key.clone());
            Plan::Join {
                left: Box::new(prune(*left, &ln)?),
                right: Box::new(prune(*right, &rn)?),
                left_key,
                right_key,
            }
        }
        Plan::Aggregate { input, key, aggs } => {
            let kept: Vec<_> = aggs
                .iter()
                .filter(|a| needed.contains(&a.out))
                .cloned()
                .collect();
            let aggs = if kept.is_empty() { aggs } else { kept };
            let mut n = BTreeSet::new();
            n.insert(key.clone());
            for a in &aggs {
                n.extend(a.input.columns_used());
            }
            Plan::Aggregate {
                input: Box::new(prune(*input, &n)?),
                key,
                aggs,
            }
        }
        Plan::Concat { inputs } => {
            // all branches must keep identical schemas: prune each with the
            // same needed set, but only if every column can be dropped from
            // every branch (sources guarantee that here)
            let mut out = Vec::new();
            for p in inputs {
                out.push(Box::new(prune(*p, needed)?));
            }
            Plan::Concat { inputs: out }
        }
        Plan::Cumsum { input, column, out } => {
            if !needed.contains(&out) {
                return prune(*input, needed);
            }
            let mut n: BTreeSet<String> =
                needed.iter().filter(|c| **c != out).cloned().collect();
            n.insert(column.clone());
            Plan::Cumsum {
                input: Box::new(prune(*input, &n)?),
                column,
                out,
            }
        }
        Plan::Stencil {
            input,
            column,
            out,
            weights,
        } => {
            if !needed.contains(&out) {
                return prune(*input, needed);
            }
            let mut n: BTreeSet<String> =
                needed.iter().filter(|c| **c != out).cloned().collect();
            n.insert(column.clone());
            Plan::Stencil {
                input: Box::new(prune(*input, &n)?),
                column,
                out,
                weights,
            }
        }
        Plan::Sort { input, key } => {
            let mut n = needed.clone();
            n.insert(key.clone());
            Plan::Sort {
                input: Box::new(prune(*input, &n)?),
                key,
            }
        }
        Plan::Rebalance { input } => Plan::Rebalance {
            input: Box::new(prune(*input, needed)?),
        },
        Plan::MatrixAssembly { input, columns } => {
            let n: BTreeSet<String> = columns.iter().cloned().collect();
            Plan::MatrixAssembly {
                input: Box::new(prune(*input, &n)?),
                columns,
            }
        }
        Plan::MlCall { input, params } => {
            let n: BTreeSet<String> = input
                .schema()?
                .names()
                .iter()
                .map(|s| s.to_string())
                .collect();
            Plan::MlCall {
                input: Box::new(prune(*input, &n)?),
                params,
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit, AggExpr, AggFn};
    use crate::ir::source_mem;
    use crate::table::Table;

    fn customer() -> Plan {
        source_mem(
            "customer",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2])),
                ("phone", Column::I64(vec![555, 666])),
            ])
            .unwrap(),
        )
    }

    fn orders() -> Plan {
        source_mem(
            "order",
            Table::from_pairs(vec![
                ("customerId", Column::I64(vec![1, 2])),
                ("amount", Column::F64(vec![50.0, 150.0])),
            ])
            .unwrap(),
        )
    }

    /// The paper's Fig. 6 example, verbatim.
    #[test]
    fn pushes_right_side_predicate_through_join() {
        let plan = Plan::Filter {
            input: Box::new(Plan::Join {
                left: Box::new(customer()),
                right: Box::new(orders()),
                left_key: "id".into(),
                right_key: "customerId".into(),
            }),
            predicate: col("amount").gt(lit(100.0)),
        };
        let opt = pushdown_predicates(plan);
        // expect Join(customer, Filter(order))
        match &opt {
            Plan::Join { left, right, .. } => {
                assert!(matches!(**left, Plan::Source { .. }));
                assert!(matches!(**right, Plan::Filter { .. }));
            }
            other => panic!("expected join at root, got:\n{other}"),
        }
        assert!(opt.schema().is_ok());
    }

    #[test]
    fn pushes_left_side_predicate_through_join() {
        let plan = Plan::Filter {
            input: Box::new(Plan::Join {
                left: Box::new(customer()),
                right: Box::new(orders()),
                left_key: "id".into(),
                right_key: "customerId".into(),
            }),
            predicate: col("phone").eq_(lit(555i64)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Join { left, right, .. } => {
                assert!(matches!(**left, Plan::Filter { .. }));
                assert!(matches!(**right, Plan::Source { .. }));
            }
            other => panic!("expected join at root, got:\n{other}"),
        }
    }

    #[test]
    fn key_predicate_pushes_with_rename() {
        // :id is the output name of the join key; pushing right requires
        // renaming it back to :customerId
        let plan = Plan::Filter {
            input: Box::new(Plan::Join {
                left: Box::new(customer()),
                right: Box::new(orders()),
                left_key: "id".into(),
                right_key: "customerId".into(),
            }),
            predicate: col("id").lt(lit(2i64)),
        };
        let opt = pushdown_predicates(plan);
        // :id exists on the left, so it pushes left (left precedence)
        match &opt {
            Plan::Join { left, .. } => assert!(matches!(**left, Plan::Filter { .. })),
            other => panic!("expected join at root, got:\n{other}"),
        }
    }

    #[test]
    fn mixed_predicate_stays_above_join() {
        let plan = Plan::Filter {
            input: Box::new(Plan::Join {
                left: Box::new(customer()),
                right: Box::new(orders()),
                left_key: "id".into(),
                right_key: "customerId".into(),
            }),
            predicate: col("phone").lt(col("amount")), // reads both sides
        };
        let opt = pushdown_predicates(plan.clone());
        match &opt {
            Plan::Filter { input, .. } => assert!(matches!(**input, Plan::Join { .. })),
            other => panic!("expected filter to stay, got:\n{other}"),
        }
    }

    #[test]
    fn filter_moves_past_unrelated_withcolumn() {
        // the paper's liveness case: array computation between relational ops
        let plan = Plan::Filter {
            input: Box::new(Plan::WithColumn {
                input: Box::new(orders()),
                name: "scaled".into(),
                expr: col("amount").mul(lit(2.0)),
            }),
            predicate: col("customerId").lt(lit(10i64)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::WithColumn { input, .. } => {
                assert!(matches!(**input, Plan::Filter { .. }));
            }
            other => panic!("expected WithColumn at root, got:\n{other}"),
        }
    }

    #[test]
    fn filter_blocked_by_dependent_withcolumn() {
        let plan = Plan::Filter {
            input: Box::new(Plan::WithColumn {
                input: Box::new(orders()),
                name: "scaled".into(),
                expr: col("amount").mul(lit(2.0)),
            }),
            predicate: col("scaled").gt(lit(100.0)), // reads the new column
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Filter { input, .. } => {
                assert!(matches!(**input, Plan::WithColumn { .. }));
            }
            other => panic!("expected blocked filter, got:\n{other}"),
        }
    }

    #[test]
    fn filter_distributes_into_concat() {
        let plan = Plan::Filter {
            input: Box::new(Plan::Concat {
                inputs: vec![Box::new(orders()), Box::new(orders())],
            }),
            predicate: col("amount").gt(lit(100.0)),
        };
        let opt = pushdown_predicates(plan);
        match &opt {
            Plan::Concat { inputs } => {
                for p in inputs {
                    assert!(matches!(**p, Plan::Filter { .. }));
                }
            }
            other => panic!("expected concat at root, got:\n{other}"),
        }
    }

    #[test]
    fn prune_inserts_projection_over_source() {
        // only :amount survives to the root → :customerId must still be
        // read (join key), :phone must be pruned from customer
        let plan = Plan::Project {
            input: Box::new(Plan::Join {
                left: Box::new(customer()),
                right: Box::new(orders()),
                left_key: "id".into(),
                right_key: "customerId".into(),
            }),
            columns: vec!["amount".into()],
        };
        let opt = prune_columns(plan).unwrap();
        let txt = format!("{opt}");
        // customer source must now be wrapped in Project(id) — no :phone
        assert!(txt.contains("Project(id)"), "plan:\n{txt}");
        assert!(opt.schema().unwrap().names() == vec!["amount"]);
    }

    #[test]
    fn prune_drops_dead_withcolumn() {
        let plan = Plan::Project {
            input: Box::new(Plan::WithColumn {
                input: Box::new(orders()),
                name: "dead".into(),
                expr: col("amount").mul(lit(0.5)),
            }),
            columns: vec!["amount".into()],
        };
        let opt = prune_columns(plan).unwrap();
        assert!(!format!("{opt}").contains("dead"), "plan:\n{opt}");
    }

    #[test]
    fn prune_keeps_agg_inputs() {
        let plan = Plan::Aggregate {
            input: Box::new(orders()),
            key: "customerId".into(),
            aggs: vec![AggExpr::new("total", AggFn::Sum, col("amount"))],
        };
        let opt = prune_columns(plan).unwrap();
        assert_eq!(opt.schema().unwrap().names(), vec!["customerId", "total"]);
    }
}
