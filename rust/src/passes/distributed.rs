//! Distributed-Pass (paper §4.4): distribution inference + rebalance
//! insertion.
//!
//! Inference itself lives on the IR ([`Plan::dist`]) since a tree needs only
//! one bottom-up meet pass. What this pass *adds* is the paper's novel
//! rebalancing policy: `1D_VAR` outputs flow freely until a consumer that
//! requires `1D_BLOCK` (halo-carrying global windows, matrix assembly),
//! where a [`Plan::Rebalance`]
//! is inserted — "the best approach is to rebalance only when necessary".
//! [`RebalanceMode::Always`] reproduces the costly alternative the paper
//! rejects, for the ablation bench.

use super::RebalanceMode;
use crate::distribution::Dist;
use crate::ir::graph::{Node, NodeId, PlanGraph, Store};
use crate::ir::Plan;

/// Insert [`Plan::Rebalance`] nodes per `mode` (tree entry point — a thin
/// round trip through [`insert_rebalances_graph`]).
pub fn insert_rebalances(plan: Plan, mode: RebalanceMode) -> Plan {
    insert_rebalances_graph(&PlanGraph::from_plan(&plan, false), mode).to_plan()
}

/// Graph rewrite: insert [`Node::Rebalance`] per `mode`. A rebalance
/// feeding a shared consumer stays shared — the balanced result is
/// materialized once per rank like any other node.
pub fn insert_rebalances_graph(g: &PlanGraph, mode: RebalanceMode) -> PlanGraph {
    match mode {
        RebalanceMode::Lazy => g.rewrite(lazy_rule),
        RebalanceMode::Always => g.rewrite(always_rule),
    }
}

/// Rebalance `input` when its distribution is `1D_VAR`.
fn wrap_if_var(st: &mut Store, input: NodeId) -> NodeId {
    if st.dist_of(input) == Dist::OneDVar {
        st.intern(Node::Rebalance { input })
    } else {
        input
    }
}

/// Lazy: only consumers that require `1D_BLOCK` inputs get a rebalance.
fn lazy_rule(st: &mut Store, node: Node) -> Node {
    if !node.requires_block_input() {
        return node;
    }
    match node {
        Node::Window {
            input,
            partition_by,
            order_by,
            aggs,
        } => {
            // only reached for halo-carrying global windows
            // (requires_block_input gates above)
            let input = wrap_if_var(st, input);
            Node::Window {
                input,
                partition_by,
                order_by,
                aggs,
            }
        }
        Node::MatrixAssembly { input, columns } => {
            let input = wrap_if_var(st, input);
            Node::MatrixAssembly { input, columns }
        }
        other => other,
    }
}

/// Always: every relational (1D_VAR-producing) node gets rebalanced right
/// away — the strawman the paper argues against.
fn always_rule(st: &mut Store, node: Node) -> Node {
    let is_relational = matches!(
        node,
        Node::Filter { .. } | Node::Join { .. } | Node::Aggregate { .. } | Node::Concat { .. }
    );
    if !is_relational {
        return node;
    }
    let id = st.intern(node);
    if st.dist_of(id) == Dist::OneDVar {
        Node::Rebalance { input: id }
    } else {
        st.node(id).clone()
    }
}

/// Count rebalance nodes (ablation metric).
pub fn count_rebalances(plan: &Plan) -> usize {
    let own = matches!(plan, Plan::Rebalance { .. }) as usize;
    own + plan
        .children()
        .iter()
        .map(|c| count_rebalances(c))
        .sum::<usize>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit};
    use crate::ir::source_mem;
    use crate::table::Table;

    fn src() -> Plan {
        source_mem(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2])),
                ("x", Column::F64(vec![0.5, 1.5])),
            ])
            .unwrap(),
        )
    }

    fn filtered() -> Plan {
        Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(1.0)),
        }
    }

    fn rolling_window(input: Plan) -> Plan {
        Plan::Window {
            input: Box::new(input),
            partition_by: vec![],
            order_by: vec![],
            aggs: vec![crate::ir::WindowAgg::new(
                "sma",
                crate::ir::WindowFunc::Weighted(vec![1.0 / 3.0; 3]),
                crate::ir::WindowFrame::Rolling {
                    preceding: 1,
                    following: 1,
                },
                col("x"),
            )],
        }
    }

    #[test]
    fn lazy_inserts_before_halo_window_only_when_var() {
        // halo window directly over a source (1D_BLOCK): no rebalance
        let opt = insert_rebalances(rolling_window(src()), RebalanceMode::Lazy);
        assert_eq!(count_rebalances(&opt), 0);

        // halo window over a filter (1D_VAR): rebalance required
        let opt = insert_rebalances(rolling_window(filtered()), RebalanceMode::Lazy);
        assert_eq!(count_rebalances(&opt), 1);
        assert_eq!(opt.dist(), Dist::OneD);

        // scans and partitioned windows need no rebalance
        let scan = Plan::Window {
            input: Box::new(filtered()),
            partition_by: vec![],
            order_by: vec![],
            aggs: vec![crate::ir::WindowAgg::new(
                "cs",
                crate::ir::WindowFunc::Sum,
                crate::ir::WindowFrame::CumulativeToCurrent,
                col("x"),
            )],
        };
        let opt = insert_rebalances(scan, RebalanceMode::Lazy);
        assert_eq!(count_rebalances(&opt), 0);
        let part = Plan::Window {
            input: Box::new(filtered()),
            partition_by: vec!["id".into()],
            order_by: vec![],
            aggs: vec![crate::ir::WindowAgg::new(
                "cs",
                crate::ir::WindowFunc::Sum,
                crate::ir::WindowFrame::CumulativeToCurrent,
                col("x"),
            )],
        };
        let opt = insert_rebalances(part, RebalanceMode::Lazy);
        assert_eq!(count_rebalances(&opt), 0);
    }

    #[test]
    fn lazy_matrix_assembly() {
        let p = Plan::MatrixAssembly {
            input: Box::new(filtered()),
            columns: vec!["x".into()],
        };
        let opt = insert_rebalances(p, RebalanceMode::Lazy);
        assert_eq!(count_rebalances(&opt), 1);
    }

    #[test]
    fn lazy_leaves_relational_chains_alone() {
        // filter → aggregate chain: no 1D_BLOCK consumers, no rebalances
        let p = Plan::Aggregate {
            input: Box::new(filtered()),
            keys: vec!["id".into()],
            aggs: vec![crate::expr::AggExpr::new(
                "n",
                crate::expr::AggFn::Count,
                col("x"),
            )],
        };
        let opt = insert_rebalances(p, RebalanceMode::Lazy);
        assert_eq!(count_rebalances(&opt), 0);
    }

    #[test]
    fn always_rebalances_every_relational_node() {
        let p = Plan::Aggregate {
            input: Box::new(filtered()),
            keys: vec!["id".into()],
            aggs: vec![crate::expr::AggExpr::new(
                "n",
                crate::expr::AggFn::Count,
                col("x"),
            )],
        };
        let opt = insert_rebalances(p, RebalanceMode::Always);
        assert_eq!(count_rebalances(&opt), 2); // after filter and aggregate
        assert_eq!(opt.dist(), Dist::OneD);
    }

    #[test]
    fn multi_key_aggregate_and_typed_joins_infer_one_d_var() {
        // distribution inference is key-set agnostic: a composite-key
        // aggregate's output size is data dependent, exactly like the
        // single-key case, and every join type meets to 1D_VAR
        let p = Plan::Aggregate {
            input: Box::new(src()),
            keys: vec!["id".into(), "x2".into()],
            aggs: vec![],
        };
        // (schema would reject :x2 — dist() is schema-independent by design)
        assert_eq!(p.dist(), Dist::OneDVar);
        for how in [
            crate::ir::JoinType::Inner,
            crate::ir::JoinType::Left,
            crate::ir::JoinType::Outer,
            crate::ir::JoinType::Anti,
        ] {
            let j = Plan::Join {
                left: Box::new(src()),
                right: Box::new(src()),
                on: vec![("id".into(), "id".into())],
                how,
                strategy: crate::ir::JoinStrategy::Hash,
            };
            assert_eq!(j.dist(), Dist::OneDVar, "{how:?}");
        }
        // a rebalance after a multi-key aggregate restores 1D
        let reb = Plan::Rebalance { input: Box::new(p) };
        assert_eq!(reb.dist(), Dist::OneD);
        // and the Always mode still wraps composite-key aggregates
        let p2 = Plan::Aggregate {
            input: Box::new(src()),
            keys: vec!["id".into()],
            aggs: vec![],
        };
        let opt = insert_rebalances(p2, RebalanceMode::Always);
        assert_eq!(count_rebalances(&opt), 1);
    }

    #[test]
    fn idempotent_on_lazy() {
        let p = rolling_window(filtered());
        let once = insert_rebalances(p, RebalanceMode::Lazy);
        let twice = insert_rebalances(once.clone(), RebalanceMode::Lazy);
        assert_eq!(count_rebalances(&once), count_rebalances(&twice));
    }
}
