//! Domain-Pass analogue (paper §4.2): plan normalization that is not
//! relational-specific — constant folding inside every expression (what the
//! paper gets "for free" from the Julia compiler) and fusion of adjacent
//! filters (the loop-fusion analogue for predicate maps: one pass over the
//! data, one output allocation).

use crate::expr::AggExpr;
use crate::ir::graph::{Node, PlanGraph};
use crate::ir::{Plan, WindowAgg};

/// Fold constants in every expression of the plan (tree entry point — a
/// thin round trip through [`fold_expressions_graph`]).
pub fn fold_expressions(plan: Plan) -> Plan {
    fold_expressions_graph(&PlanGraph::from_plan(&plan, false)).to_plan()
}

/// Graph rewrite: fold constants in every node's expressions.
pub fn fold_expressions_graph(g: &PlanGraph) -> PlanGraph {
    g.rewrite(|_, node| match node {
        Node::Filter { input, predicate } => Node::Filter {
            input,
            predicate: predicate.fold_constants(),
        },
        Node::WithColumn { input, name, expr } => Node::WithColumn {
            input,
            name,
            expr: expr.fold_constants(),
        },
        Node::Aggregate { input, keys, aggs } => Node::Aggregate {
            input,
            keys,
            aggs: aggs
                .into_iter()
                .map(|a| AggExpr {
                    input: a.input.fold_constants(),
                    ..a
                })
                .collect(),
        },
        Node::Window {
            input,
            partition_by,
            order_by,
            aggs,
        } => Node::Window {
            input,
            partition_by,
            order_by,
            aggs: aggs
                .into_iter()
                .map(|a| WindowAgg {
                    input: a.input.fold_constants(),
                    ..a
                })
                .collect(),
        },
        other => other,
    })
}

/// `Filter(Filter(x, p1), p2)` → `Filter(x, p1 && p2)` (tree entry point).
pub fn fuse_filters(plan: Plan) -> Plan {
    fuse_filters_graph(&PlanGraph::from_plan(&plan, false)).to_plan()
}

/// Graph rewrite: fuse stacked filters. Bottom-up interning means the
/// inner filter was already processed, so chains of any length collapse in
/// one sweep (the orphaned inner node becomes unreachable arena garbage).
pub fn fuse_filters_graph(g: &PlanGraph) -> PlanGraph {
    g.rewrite(|st, node| match node {
        Node::Filter { input, predicate } => match st.node(input) {
            Node::Filter {
                input: inner,
                predicate: inner_pred,
            } => {
                let (inner, inner_pred) = (*inner, inner_pred.clone());
                Node::Filter {
                    input: inner,
                    predicate: inner_pred.and(predicate),
                }
            }
            _ => Node::Filter { input, predicate },
        },
        other => other,
    })
}

/// Bottom-up plan rewriting: children first, then `f` on the rebuilt node.
/// Applied to fixpoint-free rewrites (each rule only ever shrinks or keeps
/// plan height, so one bottom-up pass suffices for the rules above; the
/// DataFrame-Pass runs its own loop).
pub fn map_plan(plan: Plan, f: &dyn Fn(Plan) -> Plan) -> Plan {
    let rebuilt = match plan {
        Plan::Source { .. } => plan,
        Plan::Filter { input, predicate } => Plan::Filter {
            input: Box::new(map_plan(*input, f)),
            predicate,
        },
        Plan::Project { input, columns } => Plan::Project {
            input: Box::new(map_plan(*input, f)),
            columns,
        },
        Plan::WithColumn { input, name, expr } => Plan::WithColumn {
            input: Box::new(map_plan(*input, f)),
            name,
            expr,
        },
        Plan::Rename { input, from, to } => Plan::Rename {
            input: Box::new(map_plan(*input, f)),
            from,
            to,
        },
        Plan::Join {
            left,
            right,
            on,
            how,
            strategy,
        } => Plan::Join {
            left: Box::new(map_plan(*left, f)),
            right: Box::new(map_plan(*right, f)),
            on,
            how,
            strategy,
        },
        Plan::Aggregate { input, keys, aggs } => Plan::Aggregate {
            input: Box::new(map_plan(*input, f)),
            keys,
            aggs,
        },
        Plan::Concat { inputs } => Plan::Concat {
            inputs: inputs
                .into_iter()
                .map(|p| Box::new(map_plan(*p, f)))
                .collect(),
        },
        Plan::Window {
            input,
            partition_by,
            order_by,
            aggs,
        } => Plan::Window {
            input: Box::new(map_plan(*input, f)),
            partition_by,
            order_by,
            aggs,
        },
        Plan::Sort { input, keys } => Plan::Sort {
            input: Box::new(map_plan(*input, f)),
            keys,
        },
        Plan::Rebalance { input } => Plan::Rebalance {
            input: Box::new(map_plan(*input, f)),
        },
        Plan::MatrixAssembly { input, columns } => Plan::MatrixAssembly {
            input: Box::new(map_plan(*input, f)),
            columns,
        },
        Plan::MlCall { input, params } => Plan::MlCall {
            input: Box::new(map_plan(*input, f)),
            params,
        },
        Plan::Cache { input } => Plan::Cache {
            input: Box::new(map_plan(*input, f)),
        },
    };
    f(rebuilt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit, Expr};
    use crate::ir::source_mem;
    use crate::table::Table;

    fn src() -> Plan {
        source_mem(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1])),
                ("x", Column::F64(vec![0.1])),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn fold_inside_filter() {
        let p = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(1.0).add(lit(2.0))),
        };
        let folded = fold_expressions(p);
        match folded {
            Plan::Filter { predicate, .. } => {
                assert_eq!(predicate, col("x").lt(lit(3.0)));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn fuse_two_filters() {
        let p = Plan::Filter {
            input: Box::new(Plan::Filter {
                input: Box::new(src()),
                predicate: col("x").gt(lit(0.0)),
            }),
            predicate: col("id").lt(lit(5i64)),
        };
        let fused = fuse_filters(p);
        assert_eq!(fused.size(), 2); // Filter + Source
        match fused {
            Plan::Filter { predicate, .. } => match predicate {
                Expr::And(_, _) => {}
                other => panic!("expected fused And, got {other:?}"),
            },
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn fuse_three_filters() {
        let mut p = src();
        for i in 0..3 {
            p = Plan::Filter {
                input: Box::new(p),
                predicate: col("id").ne_(lit(i as i64)),
            };
        }
        let fused = fuse_filters(p);
        assert_eq!(fused.size(), 2);
    }

    #[test]
    fn map_plan_reaches_all_nodes() {
        let p = Plan::Join {
            left: Box::new(src()),
            right: Box::new(Plan::Rename {
                input: Box::new(src()),
                from: "id".into(),
                to: "cid".into(),
            }),
            on: vec![("id".into(), "cid".into())],
            how: crate::ir::JoinType::Inner,
            strategy: crate::ir::JoinStrategy::Hash,
        };
        let mut count = 0usize;
        // count via a side-channel: map_plan takes Fn, so use a Cell
        let counter = std::cell::Cell::new(0usize);
        let _ = map_plan(p, &|n| {
            counter.set(counter.get() + 1);
            n
        });
        count += counter.get();
        assert_eq!(count, 4); // join, rename, two sources
    }
}
