//! Skew planner pass: pick a [`JoinStrategy`] per join from source
//! statistics.
//!
//! Paper §5.1 reports that the TPCx-BB Q05 clickstream⋈item join collapses
//! under hash partitioning when the item keys are Zipf-distributed: the few
//! hot keys all land on one rank ("high load imbalance among processors, a
//! well-known problem in the parallel database literature"). The runtime
//! mitigation is the sampled heavy-hitter broadcast path in
//! [`crate::ops::skew`]; this pass decides *when* to engage it.
//!
//! For every `Join` whose strategy is still [`JoinStrategy::Hash`] (the
//! construction default), the pass tries to estimate the maximum key-tuple
//! frequency share of the probe (left) side from the plan itself: it walks
//! through statistic-preserving nodes (`Filter`, `Sort`, `Rebalance`,
//! key-keeping `Project`/`WithColumn`, name-mapping `Rename`) down to an
//! in-memory `Source`, and takes a strided sample of the key tuple there.
//! If the sampled share of the most frequent tuple reaches the default
//! threshold, the join is flipped to [`JoinStrategy::skew_default`]; the
//! exact heavy-hitter *set* is then re-detected at run time by the
//! distributed sampling pass, so this estimate only has to be right about
//! "is there skew at all". Joins whose inputs have no reachable statistics
//! (aggregates, other joins, HFS files) and explicitly hinted joins
//! (`df.join_with(..).skew_hint(..)`) are left untouched.

use crate::column::{Column, ValidityMask};
use crate::fxhash::FxHashMap;
use crate::ir::graph::{Node, NodeId, PlanGraph, Store};
use crate::ir::{JoinStrategy, Plan, SourceRef};
use crate::ops::keys::encode_key_cells_nullable;
use crate::table::Table;

/// Rows sampled from the source table for the planner's frequency estimate.
pub const PLANNER_SAMPLE: usize = 1024;

/// Sources smaller than this never flip: the broadcast path's extra
/// collectives cannot pay off on tiny inputs, and a strided sample over a
/// handful of rows is all noise.
pub const MIN_STAT_ROWS: usize = 1000;

/// Sampled key-tuple statistics of one source table — shared by the skew
/// planner (max-share drives the broadcast flip) and the join-reorder cost
/// model (rows and NDV drive the build-side estimate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KeyStats {
    /// Exact row count of the source.
    pub rows: usize,
    /// Distinct key tuples *in the sample* (a lower bound on the true NDV).
    pub ndv: usize,
    /// Sampled frequency share of the most common key tuple.
    pub max_share: f64,
}

/// Strided-sample statistics of the key tuple `keys` in `t`, or `None`
/// when the keys are missing or not groupable. No minimum-size gate here —
/// the reorder cost model wants estimates for small dimension tables too;
/// callers that need the gate (the skew flip) apply it on `rows`.
pub fn source_key_stats(t: &Table, keys: &[String]) -> Option<KeyStats> {
    let n = t.num_rows();
    if n == 0 {
        return None;
    }
    let cols: Vec<&Column> = keys
        .iter()
        .map(|k| t.column(k))
        .collect::<Option<Vec<_>>>()?;
    if cols.iter().any(|c| !c.dtype().is_groupable()) {
        return None;
    }
    let masks: Vec<Option<&ValidityMask>> = keys.iter().map(|k| t.mask(k)).collect();
    let s = n.min(PLANNER_SAMPLE);
    // strided sample: deterministic (the optimizer must be a pure
    // function of the plan) and uniform over a block-ordered table
    let mut counts: FxHashMap<Vec<u8>, usize> = FxHashMap::default();
    let mut max = 0usize;
    for k in 0..s {
        let i = k * n / s;
        let mut row = Vec::new();
        encode_key_cells_nullable(&cols, &masks, i, &mut row);
        let c = counts.entry(row).or_insert(0);
        *c += 1;
        if *c > max {
            max = *c;
        }
    }
    Some(KeyStats {
        rows: n,
        ndv: counts.len(),
        max_share: max as f64 / s as f64,
    })
}

/// Flip `Hash` joins to `SkewBroadcast` where source statistics show a
/// heavy-hitter probe-key distribution (tree entry point — a thin round
/// trip through [`select_skew_joins_graph`]).
pub fn select_skew_joins(plan: Plan) -> Plan {
    select_skew_joins_graph(&PlanGraph::from_plan(&plan, false)).to_plan()
}

/// Graph rewrite: per-join strategy selection (see the module docs).
pub fn select_skew_joins_graph(g: &PlanGraph) -> PlanGraph {
    g.rewrite(|st, node| {
        let Node::Join {
            left,
            right,
            on,
            how,
            strategy,
        } = node
        else {
            return node;
        };
        let strategy = if strategy == JoinStrategy::Hash {
            let keys: Vec<String> = on.iter().map(|(lk, _)| lk.clone()).collect();
            let threshold =
                JoinStrategy::DEFAULT_SKEW_THRESHOLD_PERMILLE as f64 / 1000.0;
            match max_key_share_graph(st, left, &keys) {
                Some(share) if share >= threshold => JoinStrategy::skew_default(),
                _ => JoinStrategy::Hash,
            }
        } else {
            strategy
        };
        Node::Join {
            left,
            right,
            on,
            how,
            strategy,
        }
    })
}

/// Estimated frequency share of the most common key tuple of `keys` in
/// `plan`'s output, or `None` when no statistics are reachable or the
/// source is below [`MIN_STAT_ROWS`]. The walk treats `Filter` as
/// statistics-preserving (an approximation — a selective filter can change
/// the key distribution, but the runtime sampling pass corrects the heavy
/// set anyway).
pub fn max_key_share(plan: &Plan, keys: &[String]) -> Option<f64> {
    let stats = plan_key_stats(plan, keys)?;
    if stats.rows < MIN_STAT_ROWS {
        return None;
    }
    Some(stats.max_share)
}

/// Walk `plan` through statistic-preserving nodes down to an in-memory
/// source and sample the key tuple there (`None` when no statistics are
/// reachable — aggregates, other joins, HFS files). No size gate; see
/// [`source_key_stats`].
pub fn plan_key_stats(plan: &Plan, keys: &[String]) -> Option<KeyStats> {
    match plan {
        Plan::Source {
            src: SourceRef::InMemory(t),
            ..
        } => source_key_stats(t, keys),
        Plan::Filter { input, .. }
        | Plan::Sort { input, .. }
        | Plan::Rebalance { input }
        | Plan::Cache { input } => plan_key_stats(input, keys),
        Plan::Project { input, columns } => {
            if keys.iter().all(|k| columns.contains(k)) {
                plan_key_stats(input, keys)
            } else {
                None
            }
        }
        Plan::WithColumn { input, name, .. } => {
            if keys.contains(name) {
                None // the key column is (re)computed — stats unreachable
            } else {
                plan_key_stats(input, keys)
            }
        }
        Plan::Rename { input, from, to } => {
            let mapped: Vec<String> = keys
                .iter()
                .map(|k| if k == to { from.clone() } else { k.clone() })
                .collect();
            plan_key_stats(input, &mapped)
        }
        _ => None,
    }
}

/// Graph counterpart of [`max_key_share`].
pub fn max_key_share_graph(st: &Store, id: NodeId, keys: &[String]) -> Option<f64> {
    let stats = node_key_stats(st, id, keys)?;
    if stats.rows < MIN_STAT_ROWS {
        return None;
    }
    Some(stats.max_share)
}

/// Graph counterpart of [`plan_key_stats`].
pub fn node_key_stats(st: &Store, id: NodeId, keys: &[String]) -> Option<KeyStats> {
    match st.node(id) {
        Node::Source {
            src: SourceRef::InMemory(t),
            ..
        } => source_key_stats(t, keys),
        Node::Filter { input, .. }
        | Node::Sort { input, .. }
        | Node::Rebalance { input }
        | Node::Cache { input } => node_key_stats(st, *input, keys),
        Node::Project { input, columns } => {
            if keys.iter().all(|k| columns.contains(k)) {
                node_key_stats(st, *input, keys)
            } else {
                None
            }
        }
        Node::WithColumn { input, name, .. } => {
            if keys.contains(name) {
                None // the key column is (re)computed — stats unreachable
            } else {
                node_key_stats(st, *input, keys)
            }
        }
        Node::Rename { input, from, to } => {
            let mapped: Vec<String> = keys
                .iter()
                .map(|k| if k == to { from.clone() } else { k.clone() })
                .collect();
            node_key_stats(st, *input, &mapped)
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::datagen::{micro_table, skewed_table};
    use crate::ir::{source_mem, JoinType};
    use crate::table::Table;

    fn dim(n: i64) -> Plan {
        source_mem(
            "dim",
            Table::from_pairs(vec![
                ("rid", Column::I64((0..n).collect())),
                ("w", Column::I64((0..n).map(|i| i * 10).collect())),
            ])
            .unwrap(),
        )
    }

    fn join_over(left: Plan) -> Plan {
        Plan::Join {
            left: Box::new(left),
            right: Box::new(dim(100)),
            on: vec![("id".into(), "rid".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        }
    }

    fn strategy_of(plan: &Plan) -> JoinStrategy {
        match plan {
            Plan::Join { strategy, .. } => *strategy,
            other => panic!("expected join at root, got:\n{other}"),
        }
    }

    #[test]
    fn flips_above_threshold_not_below() {
        // Zipf(1.5) keys: the top key holds ~40 % of the rows — well above
        // the 10 % default threshold
        let skewed = source_mem("l", skewed_table(4000, 100, 1.5, 7));
        let opt = select_skew_joins(join_over(skewed));
        assert_eq!(strategy_of(&opt), JoinStrategy::skew_default());
        // uniform keys over 1000 distinct values: far below the threshold
        let uniform = source_mem("l", micro_table(4000, 1000, 7));
        let opt = select_skew_joins(join_over(uniform));
        assert_eq!(strategy_of(&opt), JoinStrategy::Hash);
    }

    #[test]
    fn small_sources_never_flip() {
        // heavy skew but only 60 rows: below MIN_STAT_ROWS, stays Hash
        let tiny = source_mem(
            "l",
            Table::from_pairs(vec![("id", Column::I64(vec![7; 60]))]).unwrap(),
        );
        let opt = select_skew_joins(join_over(tiny));
        assert_eq!(strategy_of(&opt), JoinStrategy::Hash);
    }

    #[test]
    fn explicit_hint_is_left_alone() {
        let uniform = source_mem("l", micro_table(4000, 1000, 7));
        let hinted = Plan::Join {
            left: Box::new(uniform),
            right: Box::new(dim(100)),
            on: vec![("id".into(), "rid".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::skew_with_threshold(0.5),
        };
        let opt = select_skew_joins(hinted);
        assert_eq!(opt.size(), 3);
        assert_eq!(
            strategy_of(&opt),
            JoinStrategy::SkewBroadcast {
                threshold_permille: 500
            }
        );
    }

    #[test]
    fn walks_through_filter_rename_project() {
        use crate::expr::{col, lit};
        let base = source_mem("l", skewed_table(4000, 100, 1.5, 9));
        let chained = Plan::Rename {
            input: Box::new(Plan::Project {
                input: Box::new(Plan::Filter {
                    input: Box::new(base),
                    predicate: col("x").lt(lit(2.0)),
                }),
                columns: vec!["id".into()],
            }),
            from: "id".into(),
            to: "key".into(),
        };
        let share = max_key_share(&chained, &["key".into()]).unwrap();
        assert!(share > 0.1, "share {share}");
        // project that drops the key stops the walk
        let dropped = Plan::Project {
            input: Box::new(source_mem("l", skewed_table(4000, 100, 1.5, 9))),
            columns: vec!["x".into()],
        };
        assert!(max_key_share(&dropped, &["id".into()]).is_none());
        // a WithColumn that recomputes the key stops it too
        let recomputed = Plan::WithColumn {
            input: Box::new(source_mem("l", skewed_table(4000, 100, 1.5, 9))),
            name: "id".into(),
            expr: col("id").rem(lit(2i64)),
        };
        assert!(max_key_share(&recomputed, &["id".into()]).is_none());
    }

    #[test]
    fn nullable_heavy_key_counts_null_group() {
        use crate::column::ValidityMask;
        // 2000 rows, all distinct values, but 60 % of them null-masked: the
        // null "key" is the heavy hitter
        let n = 2000usize;
        let t = Table::from_pairs(vec![(
            "id",
            Column::I64((0..n as i64).collect()),
        )])
        .unwrap()
        .with_null_mask(
            "id",
            ValidityMask::from_bools(
                &(0..n).map(|i| i % 5 < 2).collect::<Vec<_>>(),
            ),
        )
        .unwrap();
        let share = max_key_share(&source_mem("l", t), &["id".into()]).unwrap();
        assert!(share > 0.5, "null share {share}");
    }
}
