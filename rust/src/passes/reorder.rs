//! Cost-based join ordering over the plan graph.
//!
//! HiFrames' pipeline (paper §4) assumes the *compiler* picks the physical
//! join order; user programs write multi-way joins in whatever order reads
//! best. This pass reorders **left-deep chains of inner hash joins** so the
//! smallest estimated build side joins first, shrinking every intermediate
//! result. The estimates are free: the same strided source samples the skew
//! planner takes ([`super::skew::plan_key_stats`]) give row counts and a
//! sampled NDV per build side.
//!
//! Safety argument (why the rewrite is byte-identical up to row order):
//! a chain `((base ⋈ r1) ⋈ r2) ⋈ r3` is only reordered when every link's
//! *left* key columns come from `base` itself — then no link's key depends
//! on a column another link contributes, the inner joins commute as
//! multiset operations, and any permutation yields the same rows. The
//! output *column order* does change (each join appends its right side's
//! payload), so the rewritten chain is wrapped in a `Project` restoring the
//! original column order; row order is engine-defined for hash joins either
//! way, exactly as for the unreordered plan. Chains with unknown costs (no
//! reachable statistics on some build side) are left untouched.

use super::skew::plan_key_stats;
use crate::ir::graph::PlanGraph;
use crate::ir::{JoinStrategy, JoinType, Plan};
use std::collections::BTreeSet;

/// One `⋈ right ON on` link of a left-deep inner-join chain.
struct Link {
    right: Plan,
    on: Vec<(String, String)>,
    strategy: JoinStrategy,
}

/// Reorder inner-join chains in `g` by estimated build-side cost. The
/// rewrite is chain-local, so it round-trips through the tree form and
/// re-interns with the graph's own dedup policy.
pub fn reorder_joins_graph(g: &PlanGraph) -> PlanGraph {
    let dedup = g.store.dedup_enabled();
    PlanGraph::from_plan(&reorder_joins_plan(g.to_plan()), dedup)
}

/// Tree form of the reorder pass: top-down, so a chain is seen whole at
/// its root before recursion dismantles it.
pub fn reorder_joins_plan(plan: Plan) -> Plan {
    match try_reorder_chain(plan) {
        Ok(done) => done,
        Err(p) => p.map_children(&mut |c| reorder_joins_plan(c)),
    }
}

/// Split a left-deep chain of inner hash joins into `(base, links)`,
/// innermost link first. `Err` returns the plan untouched when it is not
/// such a join at all.
fn flatten(plan: Plan) -> Result<(Plan, Vec<Link>), Plan> {
    match plan {
        Plan::Join {
            left,
            right,
            on,
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        } => {
            let link = Link {
                right: *right,
                on,
                strategy: JoinStrategy::Hash,
            };
            match flatten(*left) {
                Ok((base, mut links)) => {
                    links.push(link);
                    Ok((base, links))
                }
                Err(base) => Ok((base, vec![link])),
            }
        }
        other => Err(other),
    }
}

/// Reassemble a flattened chain in the given link order.
fn rebuild(base: Plan, links: Vec<Link>) -> Plan {
    let mut p = base;
    for l in links {
        p = Plan::Join {
            left: Box::new(p),
            right: Box::new(l.right),
            on: l.on,
            how: JoinType::Inner,
            strategy: l.strategy,
        };
    }
    p
}

/// `Ok(reordered)` when `plan` roots an eligible chain that benefits from
/// reordering (children already recursed); `Err(plan)` — unchanged — when
/// it does not, so the caller recurses normally.
fn try_reorder_chain(plan: Plan) -> Result<Plan, Plan> {
    // snapshot the user-visible column order before dismantling
    let out_cols: Vec<String> = match plan.schema() {
        Ok(s) => s.names().iter().map(|n| n.to_string()).collect(),
        Err(_) => return Err(plan),
    };
    let (base, links) = flatten(plan)?;
    if links.len() < 2 {
        return Err(rebuild(base, links));
    }
    // eligibility: every link keys on base columns only, so no link depends
    // on a column another link contributes and the joins commute
    let base_names: BTreeSet<String> = match base.schema() {
        Ok(s) => s.names().iter().map(|n| n.to_string()).collect(),
        Err(_) => return Err(rebuild(base, links)),
    };
    let all_keys_from_base = links
        .iter()
        .all(|l| l.on.iter().all(|(lk, _)| base_names.contains(lk)));
    if !all_keys_from_base {
        return Err(rebuild(base, links));
    }
    // cost per build side: sampled row count, then key multiplicity
    // (rows / sampled NDV — a near-unique dimension key beats a repeated
    // fact key at equal size). No stats on any side ⇒ keep the user order.
    let mut est: Vec<(usize, f64)> = Vec::new();
    for l in &links {
        let keys: Vec<String> = l.on.iter().map(|(_, rk)| rk.clone()).collect();
        match plan_key_stats(&l.right, &keys) {
            Some(s) => est.push((s.rows, s.rows as f64 / s.ndv.max(1) as f64)),
            None => return Err(rebuild(base, links)),
        }
    }
    let mut order: Vec<usize> = (0..links.len()).collect();
    order.sort_by(|&a, &b| {
        est[a]
            .0
            .cmp(&est[b].0)
            .then(
                est[a]
                    .1
                    .partial_cmp(&est[b].1)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then(a.cmp(&b))
    });
    if order.iter().enumerate().all(|(i, &j)| i == j) {
        return Err(rebuild(base, links)); // user order is already optimal
    }
    // recurse into the subplans, then rebuild smallest-build-side-first
    let base = reorder_joins_plan(base);
    let links: Vec<Link> = links
        .into_iter()
        .map(|mut l| {
            l.right = reorder_joins_plan(l.right);
            l
        })
        .collect();
    let mut p = base.clone();
    for &i in &order {
        let l = &links[i];
        p = Plan::Join {
            left: Box::new(p),
            right: Box::new(l.right.clone()),
            on: l.on.clone(),
            how: JoinType::Inner,
            strategy: l.strategy,
        };
    }
    match p.schema() {
        Ok(s) => {
            let cols: Vec<String> = s.names().iter().map(|n| n.to_string()).collect();
            if cols == out_cols {
                Ok(p)
            } else {
                // same column set, different order — restore the original
                Ok(Plan::Project {
                    input: Box::new(p),
                    columns: out_cols,
                })
            }
        }
        // paranoia: a permutation that fails to type-check (should be
        // unreachable given the eligibility test) keeps the user order
        Err(_) => Ok(rebuild(base, links)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ir::source_mem;
    use crate::table::Table;

    fn base() -> Plan {
        source_mem(
            "base",
            Table::from_pairs(vec![
                ("id", Column::I64((0..40).collect())),
                ("x", Column::F64((0..40).map(|i| i as f64).collect())),
            ])
            .unwrap(),
        )
    }

    fn dim(name: &str, key: &str, payload: &str, n: i64) -> Plan {
        source_mem(
            name,
            Table::from_pairs(vec![
                (key, Column::I64((0..n).map(|i| i % 40).collect())),
                (payload, Column::I64((0..n).collect())),
            ])
            .unwrap(),
        )
    }

    fn chain(big_first: bool) -> Plan {
        let big = dim("big", "a", "av", 300);
        let small = dim("small", "b", "bv", 20);
        let (first, fon, second, son) = if big_first {
            (big, ("id", "a"), small, ("id", "b"))
        } else {
            (small, ("id", "b"), big, ("id", "a"))
        };
        Plan::Join {
            left: Box::new(Plan::Join {
                left: Box::new(base()),
                right: Box::new(first),
                on: vec![(fon.0.into(), fon.1.into())],
                how: JoinType::Inner,
                strategy: JoinStrategy::Hash,
            }),
            right: Box::new(second),
            on: vec![(son.0.into(), son.1.into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        }
    }

    #[test]
    fn smallest_build_side_moves_first() {
        let orig = chain(true);
        let orig_cols = orig.schema().unwrap().names().join(",");
        let opt = reorder_joins_plan(orig);
        // reordered chain is wrapped in a Project restoring column order
        let Plan::Project { input, columns } = opt else {
            panic!("expected project wrapper");
        };
        assert_eq!(columns.join(","), orig_cols);
        let Plan::Join { left, right, .. } = *input else {
            panic!("expected outer join");
        };
        assert!(
            matches!(&*right, Plan::Source { name, .. } if name == "big"),
            "big should join last"
        );
        let Plan::Join { right: inner_r, .. } = *left else {
            panic!("expected inner join");
        };
        assert!(
            matches!(&*inner_r, Plan::Source { name, .. } if name == "small"),
            "small should join first"
        );
    }

    #[test]
    fn optimal_user_order_untouched() {
        let orig = chain(false); // small already first
        let before = format!("{orig}");
        let opt = reorder_joins_plan(orig);
        assert_eq!(format!("{opt}"), before);
    }

    #[test]
    fn dependent_keys_block_reordering() {
        // second link keys on the *first dimension's* payload — the links
        // no longer commute, the chain must stay in user order
        let p = Plan::Join {
            left: Box::new(Plan::Join {
                left: Box::new(base()),
                right: Box::new(dim("big", "a", "av", 300)),
                on: vec![("id".into(), "a".into())],
                how: JoinType::Inner,
                strategy: JoinStrategy::Hash,
            }),
            right: Box::new(dim("small", "b", "bv", 20)),
            on: vec![("av".into(), "b".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        };
        let before = format!("{p}");
        let opt = reorder_joins_plan(p);
        assert_eq!(format!("{opt}"), before);
    }

    #[test]
    fn non_inner_links_terminate_the_chain() {
        // outer root join is Left: not a chain link — and its left child
        // chain is only one link long, so nothing moves
        let p = Plan::Join {
            left: Box::new(Plan::Join {
                left: Box::new(base()),
                right: Box::new(dim("big", "a", "av", 300)),
                on: vec![("id".into(), "a".into())],
                how: JoinType::Inner,
                strategy: JoinStrategy::Hash,
            }),
            right: Box::new(dim("small", "b", "bv", 20)),
            on: vec![("id".into(), "b".into())],
            how: JoinType::Left,
            strategy: JoinStrategy::Hash,
        };
        let before = format!("{p}");
        let opt = reorder_joins_plan(p);
        assert_eq!(format!("{opt}"), before);
    }

    #[test]
    fn graph_round_trip_preserves_dedup_policy() {
        let g = PlanGraph::from_plan(&chain(true), true);
        let out = reorder_joins_graph(&g);
        assert!(out.store.dedup_enabled());
        // the reordered graph still evaluates to the same schema
        assert_eq!(
            out.schema().unwrap().names(),
            g.schema().unwrap().names()
        );
    }
}
