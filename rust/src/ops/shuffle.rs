//! Hash-partition shuffle: route each row to `key mod nranks` (the paper's
//! hash partitioning, Fig. 5) and exchange with one `alltoallv`.

use crate::column::{
    decode_column, decode_nullable_column, encode_column_take, encode_nullable_column_take,
    extend_opt_mask, Column, ValidityMask,
};
use crate::comm::Comm;
use anyhow::Result;

/// Destination rank of a key (the paper's `_df_id[i] % npes`).
#[inline(always)]
pub fn owner_of(key: i64, nranks: usize) -> usize {
    (key.rem_euclid(nranks as i64)) as usize
}

/// Shuffle `cols` (all of equal local length) by the i64 `keys` column so
/// that every row lands on `owner_of(key)`. Returns the received columns,
/// keys first, in the same column order.
pub fn shuffle_by_key(comm: &Comm, keys: &[i64], cols: &[Column]) -> Result<(Vec<i64>, Vec<Column>)> {
    let p = comm.nranks();
    debug_assert!(cols.iter().all(|c| c.len() == keys.len()));

    // bucket row indices per destination — one counting pass then one fill
    // pass (branchless bucket count was a §Perf win over push-per-row)
    let mut counts = vec![0usize; p];
    for &k in keys {
        counts[owner_of(k, p)] += 1;
    }
    let mut buckets: Vec<Vec<usize>> = counts.iter().map(|&c| Vec::with_capacity(c)).collect();
    for (i, &k) in keys.iter().enumerate() {
        buckets[owner_of(k, p)].push(i);
    }

    // pack per-destination buffers: key column then payload columns.
    // encode_column_take fuses gather+encode (§Perf: no intermediate column)
    let key_col = Column::I64(keys.to_vec());
    let mut bufs = Vec::with_capacity(p);
    for idx in &buckets {
        let mut buf = Vec::new();
        encode_column_take(&key_col, idx, &mut buf);
        for c in cols {
            encode_column_take(c, idx, &mut buf);
        }
        bufs.push(buf);
    }

    let received = comm.alltoallv_bytes(bufs);

    // unpack: concat per-source chunks in rank order
    let mut out_keys: Vec<i64> = Vec::new();
    let mut out_cols: Vec<Column> = cols.iter().map(|c| Column::new_empty(c.dtype())).collect();
    for buf in received {
        let mut pos = 0;
        let kcol = decode_column(&buf, &mut pos)?;
        out_keys.extend_from_slice(kcol.as_i64());
        for oc in out_cols.iter_mut() {
            let c = decode_column(&buf, &mut pos)?;
            oc.extend(&c);
        }
    }
    Ok((out_keys, out_cols))
}

/// Shuffle `cols` (all of equal local length) with a precomputed destination
/// rank per row — the composite-key generalization of [`shuffle_by_key`]:
/// callers route by their packed key set (via
/// [`crate::ops::keys::PackedKeys::owners`]) and ship key columns alongside
/// the payload. Takes column *references* so the exec layer never clones a
/// column just to shuffle it. Returns the received columns in the same
/// column order, per-source chunks concatenated in rank order. Thin wrapper
/// over [`shuffle_by_owner_nullable`] (mask-free columns pay one flag byte
/// each on the wire).
pub fn shuffle_by_owner(
    comm: &Comm,
    owners: &[usize],
    cols: &[&Column],
) -> Result<Vec<Column>> {
    let masks: Vec<Option<&ValidityMask>> = vec![None; cols.len()];
    let (out, _) = shuffle_by_owner_nullable(comm, owners, cols, &masks)?;
    Ok(out)
}

/// Hash-partition shuffle over a packed key set: route every row of `cols`
/// to the owner rank of its key tuple. The keys travel as ordinary columns
/// (the leading ones of `cols`); only the routing vector comes from the
/// packed representation, so no per-row key tuple is ever materialized.
pub fn shuffle_by_packed(
    comm: &Comm,
    keys: &crate::ops::keys::PackedKeys<'_>,
    cols: &[&Column],
) -> Result<Vec<Column>> {
    let owners = keys.owners(comm.nranks());
    shuffle_by_owner(comm, &owners, cols)
}

/// Nullable variant of [`shuffle_by_owner`]: each column travels with its
/// optional validity mask (the nullable wire framing), so null positions
/// survive the redistribution. Received masks stay `None` until a source
/// chunk actually carries one (lazy materialization).
pub fn shuffle_by_owner_nullable(
    comm: &Comm,
    owners: &[usize],
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>)> {
    debug_assert!(cols.iter().all(|c| c.len() == owners.len()));
    let buckets = bucket_rows(owners, None, comm.nranks());
    shuffle_buckets(comm, &buckets, cols, masks)
}

/// [`shuffle_by_owner_nullable`] over a row *subset*: ship only the rows
/// `idx` (with `owners[k]` the destination of row `idx[k]`), encoding
/// straight from the source columns — no intermediate materialization of
/// the subset. The skew-aware join routes its light partition through this
/// so the majority of both tables is copied exactly once (into the wire
/// buffers), matching the zero-copy hash path.
pub fn shuffle_rows_by_owner_nullable(
    comm: &Comm,
    owners: &[usize],
    idx: &[usize],
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>)> {
    debug_assert_eq!(owners.len(), idx.len());
    let buckets = bucket_rows(owners, Some(idx), comm.nranks());
    shuffle_buckets(comm, &buckets, cols, masks)
}

/// Bucket row ids per destination rank — one counting pass then one fill
/// pass. With `idx`, `owners[k]` routes row `idx[k]`; without, row `k`.
fn bucket_rows(owners: &[usize], idx: Option<&[usize]>, p: usize) -> Vec<Vec<usize>> {
    let mut counts = vec![0usize; p];
    for &d in owners {
        counts[d] += 1;
    }
    let mut buckets: Vec<Vec<usize>> =
        counts.iter().map(|&c| Vec::with_capacity(c)).collect();
    match idx {
        Some(idx) => {
            for (k, &d) in owners.iter().enumerate() {
                buckets[d].push(idx[k]);
            }
        }
        None => {
            for (i, &d) in owners.iter().enumerate() {
                buckets[d].push(i);
            }
        }
    }
    buckets
}

/// Encode each destination's bucketed rows (nullable framing), exchange
/// with one `alltoallv`, and concatenate the received chunks in rank order.
fn shuffle_buckets(
    comm: &Comm,
    buckets: &[Vec<usize>],
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>)> {
    debug_assert_eq!(cols.len(), masks.len());
    let mut bufs = Vec::with_capacity(buckets.len());
    for idx in buckets {
        let mut buf = Vec::new();
        for (&c, &m) in cols.iter().zip(masks.iter()) {
            encode_nullable_column_take(c, m, idx, &mut buf);
        }
        bufs.push(buf);
    }

    let received = comm.alltoallv_bytes(bufs);

    let mut out_cols: Vec<Column> =
        cols.iter().map(|c| Column::new_empty(c.dtype())).collect();
    let mut out_masks: Vec<Option<ValidityMask>> = vec![None; cols.len()];
    for buf in received {
        let mut pos = 0;
        for (oc, om) in out_cols.iter_mut().zip(out_masks.iter_mut()) {
            let before = oc.len();
            let (c, m) = decode_nullable_column(&buf, &mut pos)?;
            oc.extend(&c);
            extend_opt_mask(om, before, m.as_ref(), c.len());
        }
    }
    Ok((out_cols, out_masks))
}

/// Nullable variant of [`shuffle_by_packed`].
pub fn shuffle_by_packed_nullable(
    comm: &Comm,
    keys: &crate::ops::keys::PackedKeys<'_>,
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>)> {
    let owners = keys.owners(comm.nranks());
    shuffle_by_owner_nullable(comm, &owners, cols, masks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;

    #[test]
    fn owner_handles_negative_keys() {
        assert_eq!(owner_of(-1, 4), 3);
        assert_eq!(owner_of(0, 4), 0);
        assert_eq!(owner_of(7, 4), 3);
    }

    #[test]
    fn shuffle_routes_to_owner() {
        let out = run_spmd(4, |c| {
            // every rank contributes keys 0..8
            let keys: Vec<i64> = (0..8).collect();
            let vals = Column::F64((0..8).map(|i| i as f64 + c.rank() as f64 * 10.0).collect());
            let (k, cols) = shuffle_by_key(&c, &keys, &[vals]).unwrap();
            (c.rank(), k, cols)
        });
        for (rank, keys, cols) in out {
            // rank r must hold exactly the keys ≡ r (mod 4), 2 per source rank
            assert_eq!(keys.len(), 8);
            assert!(keys.iter().all(|&k| owner_of(k, 4) == rank));
            assert_eq!(cols[0].len(), 8);
        }
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let out = run_spmd(3, |c| {
            let keys: Vec<i64> = (0..10).map(|i| (i * 7 + c.rank() as i64) % 5).collect();
            let vals = Column::I64(keys.iter().map(|&k| k * 100).collect());
            let (k, cols) = shuffle_by_key(&c, &keys, &[vals]).unwrap();
            (k, cols[0].as_i64().to_vec())
        });
        let mut all_keys: Vec<i64> = out.iter().flat_map(|(k, _)| k.clone()).collect();
        all_keys.sort();
        let mut expect: Vec<i64> = (0..3)
            .flat_map(|r| (0..10).map(move |i| (i * 7 + r) % 5))
            .collect();
        expect.sort();
        assert_eq!(all_keys, expect);
        // row payloads stay attached to their keys
        for (k, v) in out.iter().flat_map(|(k, v)| k.iter().zip(v.iter())) {
            assert_eq!(*v, *k * 100);
        }
    }

    #[test]
    fn shuffle_multiple_columns_and_strings() {
        let out = run_spmd(2, |c| {
            let keys = vec![0i64, 1, 2, 3];
            let a = Column::F64(vec![0.0, 0.1, 0.2, 0.3]);
            let b = Column::Str(vec!["a".into(), "b".into(), "c".into(), "d".into()]);
            let (k, cols) = shuffle_by_key(&c, &keys, &[a, b]).unwrap();
            (k, cols[1].as_str_col().to_vec())
        });
        // rank 0 gets keys 0,2 twice (from both ranks)
        assert_eq!(out[0].0.len(), 4);
        assert!(out[0].1.iter().all(|s| s == "a" || s == "c"));
        assert!(out[1].1.iter().all(|s| s == "b" || s == "d"));
    }

    #[test]
    fn shuffle_by_owner_routes_and_preserves_multiset() {
        let out = run_spmd(3, |c| {
            // rows carry (key, val); destination precomputed per row
            let keys: Vec<i64> = (0..9).map(|i| i + c.rank() as i64).collect();
            let owners: Vec<usize> = keys.iter().map(|&k| (k as usize) % 3).collect();
            let kcol = Column::I64(keys.clone());
            let vcol = Column::I64(keys.iter().map(|&k| k * 11).collect());
            let cols = shuffle_by_owner(&c, &owners, &[&kcol, &vcol]).unwrap();
            (c.rank(), cols[0].as_i64().to_vec(), cols[1].as_i64().to_vec())
        });
        let mut all: Vec<i64> = Vec::new();
        for (rank, ks, vs) in &out {
            for (k, v) in ks.iter().zip(vs) {
                assert_eq!((*k as usize) % 3, *rank, "key {k} on wrong rank");
                assert_eq!(*v, *k * 11);
                all.push(*k);
            }
        }
        all.sort();
        let mut expect: Vec<i64> = (0..3).flat_map(|r| (0..9).map(move |i| i + r)).collect();
        expect.sort();
        assert_eq!(all, expect);
    }

    #[test]
    fn shuffle_by_packed_colocates_composite_keys() {
        use crate::ops::keys::PackedKeys;
        let out = run_spmd(3, |c| {
            // composite (i64, bool) keys spread over every rank
            let ids: Vec<i64> = (0..12).map(|i| (i + c.rank() as i64) % 4).collect();
            let k1 = Column::I64(ids.clone());
            let k2 = Column::Bool(ids.iter().map(|&i| i % 2 == 0).collect());
            let packed = PackedKeys::pack(&[&k1, &k2]).unwrap();
            let cols = shuffle_by_packed(&c, &packed, &[&k1, &k2]).unwrap();
            (c.rank(), cols[0].as_i64().to_vec(), cols[1].as_bool().to_vec())
        });
        // every (k1,k2) tuple must live on exactly one rank
        let mut owner_of_tuple: std::collections::HashMap<(i64, bool), usize> =
            std::collections::HashMap::new();
        let mut total = 0usize;
        for (rank, k1s, k2s) in &out {
            for (a, b) in k1s.iter().zip(k2s) {
                total += 1;
                if let Some(prev) = owner_of_tuple.insert((*a, *b), *rank) {
                    assert_eq!(prev, *rank, "tuple ({a},{b}) split across ranks");
                }
            }
        }
        assert_eq!(total, 36);
    }

    #[test]
    fn nullable_shuffle_preserves_null_positions() {
        use crate::column::ValidityMask;
        use crate::ops::keys::PackedKeys;
        let out = run_spmd(3, |c| {
            // key i with value i*10, null where i % 3 == rank (so every rank
            // contributes different null positions)
            let keys: Vec<i64> = (0..9).collect();
            let kcol = Column::I64(keys.clone());
            let vcol = Column::I64(keys.iter().map(|&k| k * 10).collect());
            let vmask = ValidityMask::from_bools(
                &keys
                    .iter()
                    .map(|&k| (k % 3) as usize != c.rank())
                    .collect::<Vec<_>>(),
            );
            let packed = PackedKeys::pack(&[&kcol]).unwrap();
            let (cols, masks) = shuffle_by_packed_nullable(
                &c,
                &packed,
                &[&kcol, &vcol],
                &[None, Some(&vmask)],
            )
            .unwrap();
            assert!(masks[0].is_none(), "key column never grew a mask");
            (
                cols[0].as_i64().to_vec(),
                cols[1].as_i64().to_vec(),
                masks[1].clone().unwrap().to_bools(),
            )
        });
        let mut total = 0;
        for (ks, vs, valid) in &out {
            for ((k, v), ok) in ks.iter().zip(vs).zip(valid) {
                assert_eq!(*v, k * 10, "payload stays attached");
                // the row is null exactly when its origin rank == k % 3;
                // each key appears once per source rank
                total += usize::from(!ok);
            }
        }
        assert_eq!(total, 9, "one null per (key, origin-rank) pair");
    }

    #[test]
    fn subset_shuffle_matches_full_shuffle_of_taken_rows() {
        use crate::column::ValidityMask;
        // odd rows only, with a mask on the payload: routing the subset
        // straight from the source columns must equal take-then-shuffle
        let out = run_spmd(3, |c| {
            let keys: Vec<i64> = (0..12).map(|i| i + c.rank() as i64).collect();
            let kcol = Column::I64(keys.clone());
            let vcol = Column::I64(keys.iter().map(|&k| k * 11).collect());
            let vmask = ValidityMask::from_bools(
                &keys.iter().map(|&k| k % 4 != 0).collect::<Vec<_>>(),
            );
            let idx: Vec<usize> = (0..keys.len()).filter(|i| i % 2 == 1).collect();
            let owners: Vec<usize> =
                idx.iter().map(|&i| (keys[i] as usize) % 3).collect();
            let (cols, masks) = shuffle_rows_by_owner_nullable(
                &c,
                &owners,
                &idx,
                &[&kcol, &vcol],
                &[None, Some(&vmask)],
            )
            .unwrap();
            (
                c.rank(),
                cols[0].as_i64().to_vec(),
                cols[1].as_i64().to_vec(),
                masks[1].clone().map(|m| m.to_bools()),
            )
        });
        let mut total = 0usize;
        for (rank, ks, vs, valid) in &out {
            for (j, (k, v)) in ks.iter().zip(vs).enumerate() {
                assert_eq!((*k as usize) % 3, *rank, "key {k} on wrong rank");
                assert_eq!(*v, *k * 11, "payload stays attached");
                let ok = valid.as_ref().map_or(true, |b| b[j]);
                assert_eq!(ok, *k % 4 != 0, "mask bit travels with key {k}");
                total += 1;
            }
        }
        // 3 ranks × 6 odd-indexed rows each
        assert_eq!(total, 18);
    }

    #[test]
    fn shuffle_empty_local_data() {
        let out = run_spmd(2, |c| {
            let keys: Vec<i64> = if c.rank() == 0 { vec![0, 1] } else { vec![] };
            let vals = Column::I64(keys.clone());
            let (k, _) = shuffle_by_key(&c, &keys, &[vals]).unwrap();
            k
        });
        assert_eq!(out[0], vec![0]);
        assert_eq!(out[1], vec![1]);
    }
}
