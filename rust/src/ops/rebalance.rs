//! Rebalance: convert a `1D_VAR` frame (variable-length contiguous chunks)
//! to `1D_BLOCK` (equal chunks) *preserving global row order* — the
//! collective the Distributed-Pass inserts "only when necessary" (§4.4).

use crate::column::{
    decode_nullable_column, encode_nullable_column, extend_opt_mask, Column, ValidityMask,
};
use crate::comm::{block_range, Comm};
use anyhow::Result;

/// Redistribute `cols` (this rank's contiguous chunk of a globally ordered
/// frame) into 1D_BLOCK. Returns the new local chunk.
pub fn rebalance_block(comm: &Comm, cols: &[Column]) -> Result<Vec<Column>> {
    let refs: Vec<(&Column, Option<&ValidityMask>)> =
        cols.iter().map(|c| (c, None)).collect();
    let (out, _) = rebalance_block_nullable(comm, &refs)?;
    Ok(out)
}

/// Nullable [`rebalance_block`]: every column ships with its optional
/// validity mask, so null positions keep their global row order.
pub fn rebalance_block_nullable(
    comm: &Comm,
    cols: &[(&Column, Option<&ValidityMask>)],
) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>)> {
    let p = comm.nranks();
    let local_len = cols.first().map_or(0, |(c, _)| c.len());

    // establish global offsets: allgather chunk lengths
    let lens: Vec<u64> = comm
        .allgather_bytes((local_len as u64).to_le_bytes().to_vec())
        .iter()
        .map(|b| u64::from_le_bytes(b.as_slice().try_into().unwrap()))
        .collect();
    let total: usize = lens.iter().map(|&l| l as usize).sum();
    let my_start: usize = lens[..comm.rank()].iter().map(|&l| l as usize).sum();

    // ship each row range to the rank whose 1D_BLOCK target covers it
    let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
    for (dst, buf) in bufs.iter_mut().enumerate() {
        let (tstart, tlen) = block_range(total, p, dst);
        let tend = tstart + tlen;
        // intersect [my_start, my_start+local_len) with [tstart, tend)
        let lo = my_start.max(tstart);
        let hi = (my_start + local_len).min(tend);
        if lo < hi {
            for (c, m) in cols {
                encode_nullable_column(
                    &c.slice(lo - my_start, hi - lo),
                    m.map(|m| m.slice(lo - my_start, hi - lo)).as_ref(),
                    buf,
                );
            }
        } else {
            // explicit empty marker: zero columns — receiver detects by len
        }
        let _ = dst;
    }
    let received = comm.alltoallv_bytes(bufs);

    let mut out: Vec<Column> = cols
        .iter()
        .map(|(c, _)| Column::new_empty(c.dtype()))
        .collect();
    let mut out_masks: Vec<Option<ValidityMask>> = vec![None; cols.len()];
    for buf in received {
        if buf.is_empty() {
            continue;
        }
        let mut pos = 0;
        for (oc, om) in out.iter_mut().zip(out_masks.iter_mut()) {
            let before = oc.len();
            let (c, m) = decode_nullable_column(&buf, &mut pos)?;
            oc.extend(&c);
            extend_opt_mask(om, before, m.as_ref(), c.len());
        }
    }
    Ok((out, out_masks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;

    #[test]
    fn rebalances_to_blocks_preserving_order() {
        // rank r holds r+1 rows with globally increasing values
        let out = run_spmd(4, |c| {
            let start: i64 = (0..c.rank() as i64).map(|r| r + 1).sum();
            let vals: Vec<i64> = (0..=c.rank() as i64).map(|i| start + i).collect();
            let cols = vec![Column::I64(vals)];
            let out = rebalance_block(&c, &cols).unwrap();
            out[0].as_i64().to_vec()
        });
        // total = 1+2+3+4 = 10 rows → chunks of ceil(10/4)=3: 3,3,3,1
        assert_eq!(out[0], vec![0, 1, 2]);
        assert_eq!(out[1], vec![3, 4, 5]);
        assert_eq!(out[2], vec![6, 7, 8]);
        assert_eq!(out[3], vec![9]);
    }

    #[test]
    fn already_balanced_is_stable() {
        let out = run_spmd(2, |c| {
            let vals: Vec<i64> = (0..3).map(|i| c.rank() as i64 * 3 + i).collect();
            let cols = vec![Column::I64(vals.clone())];
            let out = rebalance_block(&c, &cols).unwrap();
            out[0].as_i64().to_vec()
        });
        assert_eq!(out[0], vec![0, 1, 2]);
        assert_eq!(out[1], vec![3, 4, 5]);
    }

    #[test]
    fn extreme_skew_all_on_one_rank() {
        let out = run_spmd(3, |c| {
            let vals: Vec<i64> = if c.rank() == 2 { (0..9).collect() } else { vec![] };
            let cols = vec![
                Column::I64(vals.clone()),
                Column::Str(vals.iter().map(|v| format!("s{v}")).collect()),
            ];
            let out = rebalance_block(&c, &cols).unwrap();
            (out[0].as_i64().to_vec(), out[1].len())
        });
        assert_eq!(out[0].0, vec![0, 1, 2]);
        assert_eq!(out[1].0, vec![3, 4, 5]);
        assert_eq!(out[2].0, vec![6, 7, 8]);
        assert!(out.iter().all(|(k, sl)| k.len() == *sl));
    }

    #[test]
    fn empty_global_frame() {
        let out = run_spmd(2, |c| {
            let cols = vec![Column::F64(vec![])];
            let out = rebalance_block(&c, &cols).unwrap();
            out[0].len()
        });
        assert_eq!(out, vec![0, 0]);
    }
}
