//! Distributed aggregation over composite keys (paper §4.5): shuffle rows so
//! equal key *tuples* meet on their owner rank, then hash-table aggregation
//! (the paper's `agg1_table[key]` loop in Fig. 5, with the key generalized
//! from one i64 to a [`KeyRow`]).
//!
//! Null model: null key cells form their own group (null == null, the
//! Pandas rule), routed through the validity-flagged packed layout; null
//! *input* rows are skipped by every reduction (`sum`/`mean`/… over the
//! valid rows only, `count` = valid count). A group whose inputs are all
//! null yields 0 for `sum`/`count` and NULL for `mean`/`var`/`min`/`max`/
//! `first` (see [`agg_output_nullable`]).
//!
//! Two strategies, ablated in `benches/ablations.rs`:
//! * **raw shuffle** — ship `(key cols, expr values)` rows, aggregate after.
//!   This is exactly the paper's codegen.
//! * **local pre-aggregation** — fold rows into decomposed partial states
//!   ([`AggState`]) per key *before* the shuffle, ship
//!   `[key row, states…]` records, merge after. A classic combiner; wins
//!   when keys repeat within ranks (§Perf).

use super::join::{concat_nullable, MaskedCol};
use super::keys::{
    cmp_key_rows, decode_key_row, encode_key_cells_nullable, group_packed, key_columns,
    key_rows_nullable, skip_key_row, KeyNullability, KeyRow, PackedKeys,
};
use super::shuffle::shuffle_by_packed_nullable;
use super::spill::{masked_bytes, nullable_bytes, PartitionStore, SpillCtx, MAX_SPILL_DEPTH};
use crate::column::{Column, NullableColumn, ValidityMask};
use crate::comm::Comm;
use crate::expr::{AggFn, AggState};
use crate::fxhash::FxHashMap;
use crate::types::DType;
use anyhow::{bail, Result};

/// Which aggregation strategy to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggStrategy {
    RawShuffle,
    PreAggregate,
}

/// One reduction spec: function + dtype of its (already evaluated)
/// expression column.
#[derive(Debug, Clone, Copy)]
pub struct AggSpec {
    pub func: AggFn,
    pub input_dtype: DType,
}

/// May this reduction produce NULL (when its group's inputs are all null)?
/// `sum`/`count`/`count_distinct` have natural empty values (0); the order
/// and moment statistics do not.
pub fn agg_output_nullable(func: AggFn) -> bool {
    crate::expr::func_output_nullable(func)
}

/// Aggregate `expr_cols[i]` under `specs[i]` grouped by the composite key
/// columns (all with optional validity masks), distributed over `comm`.
/// Returns the local shard of the result: unique key tuples owned by this
/// rank (one output column per key column, dtype preserved, null keys kept)
/// plus one value column per spec. Output distribution: `1D_VAR`.
pub fn distributed_aggregate_keys(
    comm: &Comm,
    key_cols: &[MaskedCol],
    expr_cols: &[MaskedCol],
    specs: &[AggSpec],
    strategy: AggStrategy,
    nullability: KeyNullability,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    distributed_aggregate_keys_budgeted(
        comm,
        key_cols,
        expr_cols,
        specs,
        strategy,
        nullability,
        &SpillCtx::unlimited(),
    )
}

/// [`distributed_aggregate_keys`] under a per-rank memory budget. When the
/// post-shuffle working set exceeds `spill`'s budget, the raw-shuffle
/// strategy's local aggregation becomes two-phase: rows are hash-
/// partitioned to disk on the key tuple, each partition is aggregated in
/// memory (recursing up to [`MAX_SPILL_DEPTH`] on oversized partitions),
/// and the per-partition results are merged partition-at-a-time. The
/// pre-aggregation strategy keeps its in-memory combiner — its hash table
/// holds one decomposed state per *distinct* key, which is exactly the
/// shape that shrinks under the budget's pressure.
pub fn distributed_aggregate_keys_budgeted(
    comm: &Comm,
    key_cols: &[MaskedCol],
    expr_cols: &[MaskedCol],
    specs: &[AggSpec],
    strategy: AggStrategy,
    nullability: KeyNullability,
    spill: &SpillCtx,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    assert_eq!(expr_cols.len(), specs.len());
    if key_cols.is_empty() {
        bail!("aggregate: key column list must be non-empty");
    }
    let p = comm.nranks();
    let kc: Vec<&Column> = key_cols.iter().map(|(c, _)| *c).collect();
    let km: Vec<Option<&ValidityMask>> = key_cols.iter().map(|(_, m)| *m).collect();
    // flagged-vs-plain key layout must be agreed globally (the owner rank of
    // a key tuple is a function of its packed bytes); statically typed plans
    // resolve the choice from the schema with no collective
    let with_flags = nullability.with_flags(comm, km.iter().any(|m| m.is_some()));
    let packed = PackedKeys::pack_masked(&kc, &km, with_flags)?;
    match strategy {
        AggStrategy::RawShuffle => {
            let mut all: Vec<&Column> = kc.clone();
            let mut masks: Vec<Option<&ValidityMask>> = km.clone();
            for (c, m) in expr_cols {
                all.push(c);
                masks.push(*m);
            }
            let (all, rmasks) = shuffle_by_packed_nullable(comm, &packed, &all, &masks)?;
            let (rkc, rec) = all.split_at(key_cols.len());
            let (rkm, rem) = rmasks.split_at(key_cols.len());
            let krefs: Vec<MaskedCol> = rkc
                .iter()
                .zip(rkm)
                .map(|(c, m)| (c, m.as_ref()))
                .collect();
            let erefs: Vec<MaskedCol> = rec
                .iter()
                .zip(rem)
                .map(|(c, m)| (c, m.as_ref()))
                .collect();
            local_packed_aggregate_budgeted(&krefs, &erefs, specs, spill)
        }
        AggStrategy::PreAggregate => {
            // fold locally into partial states per packed key group,
            // skipping null input rows
            let groups = group_packed(&packed);
            let mut states: Vec<Vec<AggState>> = Vec::with_capacity(groups.num_groups());
            for (i, &g) in groups.group_of_row.iter().enumerate() {
                if g as usize == states.len() {
                    states.push(new_states(specs));
                }
                for (s, (c, m)) in states[g as usize].iter_mut().zip(expr_cols) {
                    if m.map_or(true, |m| m.get(i)) {
                        s.update_col(c, i);
                    }
                }
            }
            // serialize per destination: [key row, state0, state1, …]
            // records, key cells wire-encoded straight from the columns
            // (null cells as the null tag)
            let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
            for (g, &rep) in groups.rep_rows.iter().enumerate() {
                let buf = &mut bufs[packed.owner(rep as usize, p)];
                encode_key_cells_nullable(&kc, &km, rep as usize, buf);
                for s in &states[g] {
                    s.encode(buf);
                }
            }
            let received = comm.alltoallv_bytes(bufs);
            // merge incoming partials, keyed on the raw encoded key bytes
            // (the wire format is injective — the null tag included — so
            // byte equality is tuple equality)
            let mut merged: FxHashMap<Vec<u8>, Vec<AggState>> = FxHashMap::default();
            for buf in received {
                let mut pos = 0;
                while pos < buf.len() {
                    let kstart = pos;
                    skip_key_row(key_cols.len(), &buf, &mut pos)?;
                    let kbytes = buf[kstart..pos].to_vec();
                    let incoming: Vec<AggState> = specs
                        .iter()
                        .map(|sp| AggState::decode(sp.func, sp.input_dtype, &buf, &mut pos))
                        .collect();
                    match merged.entry(kbytes) {
                        std::collections::hash_map::Entry::Occupied(mut e) => {
                            for (a, b) in e.get_mut().iter_mut().zip(&incoming) {
                                a.merge(b);
                            }
                        }
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert(incoming);
                        }
                    }
                }
            }
            // decode one tuple per surviving group; deterministic asc order
            // (nulls first, per KeyVal's ordering)
            let mut entries: Vec<(KeyRow, Vec<AggState>)> = Vec::with_capacity(merged.len());
            for (kb, st) in merged {
                let mut pos = 0;
                entries.push((decode_key_row(key_cols.len(), &kb, &mut pos)?, st));
            }
            entries.sort_by(|a, b| cmp_key_rows(&a.0, &b.0, &[]));
            let mut rows: Vec<KeyRow> = Vec::with_capacity(entries.len());
            let mut outs = new_outputs(specs);
            for (k, st) in entries {
                rows.push(k);
                push_outputs(&mut outs, specs, &st);
            }
            let key_out = key_columns(&rows, &kc);
            Ok((key_out, finish_outputs(outs)))
        }
    }
}

/// Purely local aggregation over a *packed* key set — the HiFrames
/// post-shuffle half: dense group ids from [`group_packed`], one state
/// vector per group (null input rows skipped), key columns rebuilt by
/// gathering the group representatives. Output rows are sorted by ascending
/// key tuple (nulls first) so runs are reproducible — the same order as the
/// KeyRow reference path.
pub fn local_packed_aggregate(
    key_cols: &[MaskedCol],
    expr_cols: &[MaskedCol],
    specs: &[AggSpec],
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    if key_cols.is_empty() {
        bail!("aggregate: key column list must be non-empty");
    }
    let kc: Vec<&Column> = key_cols.iter().map(|(c, _)| *c).collect();
    let km: Vec<Option<&ValidityMask>> = key_cols.iter().map(|(_, m)| *m).collect();
    let packed = PackedKeys::pack_nullable(&kc, &km)?;
    let groups = group_packed(&packed);
    let mut states: Vec<Vec<AggState>> = Vec::with_capacity(groups.num_groups());
    for (i, &g) in groups.group_of_row.iter().enumerate() {
        if g as usize == states.len() {
            states.push(new_states(specs));
        }
        for (s, (c, m)) in states[g as usize].iter_mut().zip(expr_cols) {
            if m.map_or(true, |m| m.get(i)) {
                s.update_col(c, i);
            }
        }
    }
    // deterministic output order: ascending key tuples (nulls first)
    let mut order: Vec<usize> = (0..groups.num_groups()).collect();
    order.sort_by(|&a, &b| {
        packed.cmp_rows(
            groups.rep_rows[a] as usize,
            &packed,
            groups.rep_rows[b] as usize,
        )
    });
    let rep_idx: Vec<usize> = order.iter().map(|&g| groups.rep_rows[g] as usize).collect();
    let key_out: Vec<NullableColumn> = key_cols
        .iter()
        .map(|(c, m)| {
            NullableColumn::new(c.take(&rep_idx), m.map(|m| m.take(&rep_idx)))
        })
        .collect();
    let mut outs = new_outputs(specs);
    for &g in &order {
        push_outputs(&mut outs, specs, &states[g]);
    }
    Ok((key_out, finish_outputs(outs)))
}

/// [`local_packed_aggregate`] under a memory budget: in-memory when the
/// working set fits, two-phase spillable aggregation otherwise.
pub fn local_packed_aggregate_budgeted(
    key_cols: &[MaskedCol],
    expr_cols: &[MaskedCol],
    specs: &[AggSpec],
    spill: &SpillCtx,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    if key_cols.is_empty() {
        bail!("aggregate: key column list must be non-empty");
    }
    if !spill.should_spill(masked_bytes(key_cols) + masked_bytes(expr_cols)) {
        return local_packed_aggregate(key_cols, expr_cols, specs);
    }
    spill_aggregate(key_cols, expr_cols, specs, spill, 0)
}

/// Two-phase spillable aggregation, **byte-identical** to
/// [`local_packed_aggregate`]:
///
/// * Rows are hash-partitioned to disk on the full key tuple, so every
///   group lives inside exactly one partition, and each partition keeps
///   its rows in original relative order — each group therefore folds its
///   inputs in exactly the in-memory order (floating-point accumulation
///   included).
/// * Per-partition results are concatenated and re-sorted by the same
///   packed key-tuple comparator the in-memory path sorts by; group keys
///   are globally unique, so the order (and every output byte) matches.
fn spill_aggregate(
    key_cols: &[MaskedCol],
    expr_cols: &[MaskedCol],
    specs: &[AggSpec],
    spill: &SpillCtx,
    level: u32,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    let kc: Vec<&Column> = key_cols.iter().map(|(c, _)| *c).collect();
    let km: Vec<Option<&ValidityMask>> = key_cols.iter().map(|(_, m)| *m).collect();
    let packed = PackedKeys::pack_nullable(&kc, &km)?;
    let n = packed.len();
    let hashes: Vec<u64> = (0..n).map(|i| packed.hash_row(i)).collect();
    drop(packed);

    let total = masked_bytes(key_cols) + masked_bytes(expr_cols);
    let nparts = spill.budget().partition_count(total);
    let all: Vec<MaskedCol> = key_cols.iter().chain(expr_cols).copied().collect();
    let mut store = PartitionStore::partition(spill, "agg", nparts, level, &hashes, &all)?;

    let nk = key_cols.len();
    let mut acc: Option<(Vec<NullableColumn>, Vec<NullableColumn>)> = None;
    for p in 0..nparts {
        let (cols, masks) = store.read_part(p)?;
        spill.record_merge_pass();
        let (kcols, ecols) = cols.split_at(nk);
        let (kms, ems) = masks.split_at(nk);
        let krefs: Vec<MaskedCol> = kcols.iter().zip(kms).map(|(c, m)| (c, m.as_ref())).collect();
        let erefs: Vec<MaskedCol> = ecols.iter().zip(ems).map(|(c, m)| (c, m.as_ref())).collect();
        let part_rows = kcols.first().map_or(0, |c| c.len());
        let recurse = level + 1 < MAX_SPILL_DEPTH
            && part_rows < n
            && spill.should_spill(nullable_bytes(&cols, &masks));
        let (pk, pv) = if recurse {
            spill_aggregate(&krefs, &erefs, specs, spill, level + 1)?
        } else {
            local_packed_aggregate(&krefs, &erefs, specs)?
        };
        acc = Some(match acc {
            None => (pk, pv),
            Some((ak, av)) => (
                ak.into_iter()
                    .zip(&pk)
                    .map(|(a, b)| concat_nullable(a, b))
                    .collect(),
                av.into_iter()
                    .zip(&pv)
                    .map(|(a, b)| concat_nullable(a, b))
                    .collect(),
            ),
        });
    }
    let (keys, vals) = acc.expect("partition_count is at least 2");

    // Global group order: the same ascending packed-tuple comparator the
    // in-memory path uses. Keys are unique, so unstable sort is exact.
    let kc2: Vec<&Column> = keys.iter().map(|c| &c.values).collect();
    let km2: Vec<Option<&ValidityMask>> = keys.iter().map(|c| c.validity.as_ref()).collect();
    let packed2 = PackedKeys::pack_nullable(&kc2, &km2)?;
    let mut order: Vec<usize> = (0..packed2.len()).collect();
    order.sort_unstable_by(|&a, &b| packed2.cmp_rows(a, &packed2, b));
    drop(packed2);
    let reorder = |cols: Vec<NullableColumn>| -> Vec<NullableColumn> {
        cols.into_iter()
            .map(|c| {
                NullableColumn::new(
                    c.values.take(&order),
                    c.validity.as_ref().map(|m| m.take(&order)),
                )
            })
            .collect()
    };
    Ok((reorder(keys), reorder(vals)))
}

/// Purely local hash aggregation over composite keys via materialized
/// [`KeyRow`] tuples — the reference implementation, kept as the serial
/// baseline's path so engine-agreement tests cross-check the packed fast
/// path ([`local_packed_aggregate`]) against an independent one. Output rows
/// are sorted by key tuple (nulls first) so runs are reproducible.
pub fn local_hash_aggregate_keys(
    key_cols: &[MaskedCol],
    expr_cols: &[MaskedCol],
    specs: &[AggSpec],
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    let kc: Vec<&Column> = key_cols.iter().map(|(c, _)| *c).collect();
    let km: Vec<Option<&ValidityMask>> = key_cols.iter().map(|(_, m)| *m).collect();
    let rows = key_rows_nullable(&kc, &km)?;
    let mut table: FxHashMap<KeyRow, Vec<AggState>> = FxHashMap::default();
    for (i, k) in rows.into_iter().enumerate() {
        let states = table.entry(k).or_insert_with(|| new_states(specs));
        for (s, (c, m)) in states.iter_mut().zip(expr_cols) {
            if m.map_or(true, |m| m.get(i)) {
                s.update_col(c, i);
            }
        }
    }
    Ok(finish_table(table, specs, &kc))
}

/// Single-i64-key local aggregation — the seed API, kept as a wrapper.
pub fn local_hash_aggregate(
    keys: &[i64],
    expr_cols: &[Column],
    specs: &[AggSpec],
) -> (Vec<i64>, Vec<Column>) {
    let kc = Column::I64(keys.to_vec());
    let erefs: Vec<MaskedCol> = expr_cols.iter().map(|c| (c, None)).collect();
    let (kcols, outs) = local_hash_aggregate_keys(&[(&kc, None)], &erefs, specs)
        .expect("i64 keys are always groupable");
    (
        kcols[0].values.as_i64().to_vec(),
        outs.into_iter().map(|c| c.values).collect(),
    )
}

/// Single-i64-key distributed aggregation — the seed API, kept as a wrapper.
pub fn distributed_aggregate(
    comm: &Comm,
    keys: &[i64],
    expr_cols: &[Column],
    specs: &[AggSpec],
    strategy: AggStrategy,
) -> Result<(Vec<i64>, Vec<Column>)> {
    let kc = Column::I64(keys.to_vec());
    let erefs: Vec<MaskedCol> = expr_cols.iter().map(|c| (c, None)).collect();
    // a caller-built plain i64 key is non-nullable by construction
    let (kcols, outs) = distributed_aggregate_keys(
        comm,
        &[(&kc, None)],
        &erefs,
        specs,
        strategy,
        KeyNullability::Static(false),
    )?;
    Ok((
        kcols[0].values.as_i64().to_vec(),
        outs.into_iter().map(|c| c.values).collect(),
    ))
}

pub(crate) fn new_states(specs: &[AggSpec]) -> Vec<AggState> {
    specs
        .iter()
        .map(|sp| AggState::new(sp.func, sp.input_dtype))
        .collect()
}

/// Output dtype of one aggregation spec.
pub(crate) fn agg_output_dtype(sp: &AggSpec) -> DType {
    match (sp.func, sp.input_dtype) {
        (AggFn::Count | AggFn::CountDistinct, _) => DType::I64,
        (AggFn::Mean | AggFn::Var, _) => DType::F64,
        (AggFn::Sum | AggFn::Min | AggFn::Max, DType::I64 | DType::Bool) => DType::I64,
        (AggFn::Sum | AggFn::Min | AggFn::Max, _) => DType::F64,
        (AggFn::First, dt) => dt,
    }
}

pub(crate) fn new_outputs(specs: &[AggSpec]) -> Vec<(Column, ValidityMask)> {
    specs
        .iter()
        .map(|sp| {
            (
                Column::new_empty(agg_output_dtype(sp)),
                ValidityMask::new_null(0),
            )
        })
        .collect()
}

/// Append one group's finished reductions: an all-null group's order/moment
/// statistics become NULL, everything else pushes its scalar.
pub(crate) fn push_outputs(
    outs: &mut [(Column, ValidityMask)],
    specs: &[AggSpec],
    states: &[AggState],
) {
    for (((out, mask), sp), s) in outs.iter_mut().zip(specs).zip(states) {
        if agg_output_nullable(sp.func) && s.is_empty() {
            out.push(&out.dtype().default_value());
            mask.push(false);
        } else {
            out.push(&s.finish());
            mask.push(true);
        }
    }
}

pub(crate) fn finish_outputs(outs: Vec<(Column, ValidityMask)>) -> Vec<NullableColumn> {
    outs.into_iter()
        .map(|(c, m)| NullableColumn::new(c, Some(m)))
        .collect()
}

fn finish_table(
    table: FxHashMap<KeyRow, Vec<AggState>>,
    specs: &[AggSpec],
    key_templates: &[&Column],
) -> (Vec<NullableColumn>, Vec<NullableColumn>) {
    // deterministic output order (lexicographically sorted key tuples,
    // nulls first) so runs are reproducible
    let mut keys: Vec<&KeyRow> = table.keys().collect();
    keys.sort();
    let mut outs = new_outputs(specs);
    for k in &keys {
        push_outputs(&mut outs, specs, &table[*k]);
    }
    let sorted_rows: Vec<KeyRow> = keys.into_iter().cloned().collect();
    let key_out = key_columns(&sorted_rows, key_templates);
    (key_out, finish_outputs(outs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;

    fn specs() -> Vec<AggSpec> {
        vec![
            AggSpec {
                func: AggFn::Sum,
                input_dtype: DType::F64,
            },
            AggSpec {
                func: AggFn::Count,
                input_dtype: DType::F64,
            },
            AggSpec {
                func: AggFn::Mean,
                input_dtype: DType::F64,
            },
        ]
    }

    #[test]
    fn local_agg_basics() {
        let keys = vec![1i64, 2, 1, 2, 1];
        let vals = Column::F64(vec![1.0, 10.0, 2.0, 20.0, 3.0]);
        let (k, outs) =
            local_hash_aggregate(&keys, &[vals.clone(), vals.clone(), vals], &specs());
        assert_eq!(k, vec![1, 2]);
        assert_eq!(outs[0].as_f64(), &[6.0, 30.0]);
        assert_eq!(outs[1].as_i64(), &[3, 2]);
        assert_eq!(outs[2].as_f64(), &[2.0, 15.0]);
    }

    #[test]
    fn local_agg_composite_keys() {
        // (k1, k2) pairs: (1,"a") twice, (1,"b") once, (2,"a") once
        let k1 = Column::I64(vec![1, 1, 1, 2]);
        let k2 = Column::Str(vec!["a".into(), "b".into(), "a".into(), "a".into()]);
        let vals = Column::F64(vec![10.0, 20.0, 30.0, 40.0]);
        let (kcols, outs) = local_hash_aggregate_keys(
            &[(&k1, None), (&k2, None)],
            &[(&vals, None)],
            &specs()[..1],
        )
        .unwrap();
        // sorted key-tuple order: (1,a), (1,b), (2,a)
        assert_eq!(kcols[0].values.as_i64(), &[1, 1, 2]);
        assert_eq!(
            kcols[1].values.as_str_col(),
            &["a".to_string(), "b".into(), "a".into()]
        );
        assert_eq!(outs[0].values.as_f64(), &[40.0, 20.0, 40.0]);
        // single-column grouping would have produced 2 groups, not 3
    }

    #[test]
    fn packed_aggregate_matches_keyrow_reference() {
        // composite (i64, str) keys → Bytes layout; (i64, bool) → Fixed;
        // single i64 → zero-copy. All must agree with the KeyRow path.
        let k1 = Column::I64(vec![2, 1, 2, 1, 2]);
        let k2 = Column::Str(vec!["a".into(), "b".into(), "a".into(), "".into(), "b".into()]);
        let k3 = Column::Bool(vec![true, false, true, false, true]);
        let vals = Column::F64(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let sp = specs();
        for key_set in [vec![&k1], vec![&k1, &k3], vec![&k1, &k2], vec![&k1, &k2, &k3]] {
            let masked: Vec<MaskedCol> = key_set.iter().map(|&c| (c, None)).collect();
            let evals: Vec<MaskedCol> = vec![(&vals, None); 3];
            let (pk, po) = local_packed_aggregate(&masked, &evals, &sp).unwrap();
            let (rk, ro) = local_hash_aggregate_keys(&masked, &evals, &sp).unwrap();
            assert_eq!(pk, rk, "key columns for {} keys", key_set.len());
            assert_eq!(po, ro, "agg outputs for {} keys", key_set.len());
        }
    }

    #[test]
    fn null_skipping_and_null_key_groups_match_reference() {
        use crate::column::ValidityMask;
        // nullable key: rows 0 and 3 have null keys (scrubbed to 0);
        // nullable input: rows 1 and 4 are null inputs
        let k = Column::I64(vec![0, 7, 7, 0, 9]);
        let kmask = ValidityMask::from_bools(&[false, true, true, false, true]);
        let v = Column::F64(vec![1.0, 0.0, 3.0, 4.0, 0.0]);
        let vmask = ValidityMask::from_bools(&[true, false, true, true, false]);
        let sp = vec![
            AggSpec { func: AggFn::Sum, input_dtype: DType::F64 },
            AggSpec { func: AggFn::Count, input_dtype: DType::F64 },
            AggSpec { func: AggFn::Mean, input_dtype: DType::F64 },
        ];
        let keys: Vec<MaskedCol> = vec![(&k, Some(&kmask))];
        let evals: Vec<MaskedCol> = vec![(&v, Some(&vmask)); 3];
        let (pk, po) = local_packed_aggregate(&keys, &evals, &sp).unwrap();
        let (rk, ro) = local_hash_aggregate_keys(&keys, &evals, &sp).unwrap();
        assert_eq!(pk, rk);
        assert_eq!(po, ro);
        // groups in nulls-first order: null, 7, 9
        assert_eq!(pk[0].values.as_i64(), &[0, 7, 9]);
        assert_eq!(
            pk[0].validity.as_ref().unwrap().to_bools(),
            vec![false, true, true]
        );
        // null group: rows 0,3 valid inputs sum 5.0 count 2
        assert_eq!(po[0].values.as_f64(), &[5.0, 3.0, 0.0]);
        assert_eq!(po[1].values.as_i64(), &[2, 1, 0]);
        // group 9 has only a null input → mean is NULL, sum/count are 0
        assert!(po[2].is_valid(0) && po[2].is_valid(1));
        assert!(!po[2].is_valid(2), "all-null group's mean must be NULL");
        assert!(po[0].validity.is_none() && po[1].validity.is_none());
    }

    #[test]
    fn distributed_strategies_agree() {
        for strategy in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
            let out = run_spmd(3, |c| {
                // every rank holds keys (rank..rank+6) % 4 with value = key
                let keys: Vec<i64> = (0..6).map(|i| ((c.rank() + i) % 4) as i64).collect();
                let vals = Column::F64(keys.iter().map(|&k| k as f64).collect());
                let (k, outs) = distributed_aggregate(
                    &c,
                    &keys,
                    &[vals.clone(), vals.clone(), vals],
                    &specs(),
                    strategy,
                )
                .unwrap();
                (k, outs[0].as_f64().to_vec(), outs[1].as_i64().to_vec())
            });
            // collect global result
            let mut rows: Vec<(i64, f64, i64)> = out
                .iter()
                .flat_map(|(k, s, n)| {
                    k.iter()
                        .zip(s.iter())
                        .zip(n.iter())
                        .map(|((&k, &s), &n)| (k, s, n))
                })
                .collect();
            rows.sort_by_key(|r| r.0);
            // serial oracle over the same global data
            let mut all_keys = Vec::new();
            for r in 0..3usize {
                for i in 0..6usize {
                    all_keys.push(((r + i) % 4) as i64);
                }
            }
            let mut expect: std::collections::BTreeMap<i64, (f64, i64)> = Default::default();
            for &k in &all_keys {
                let e = expect.entry(k).or_insert((0.0, 0));
                e.0 += k as f64;
                e.1 += 1;
            }
            let expect: Vec<(i64, f64, i64)> =
                expect.into_iter().map(|(k, (s, n))| (k, s, n)).collect();
            assert_eq!(rows, expect, "strategy {strategy:?}");
            // each key lives on exactly one rank
            let mut owners = std::collections::HashSet::new();
            for (k, _, _) in &rows {
                assert!(owners.insert(*k), "key {k} appears on two ranks");
            }
        }
    }

    #[test]
    fn distributed_nullable_keys_single_owner_per_group() {
        use crate::column::ValidityMask;
        // nullable keys where only rank 0 holds a mask: the null group and
        // every valid key must still each land on exactly one rank, for both
        // strategies (global layout agreement)
        for strategy in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
            let out = run_spmd(3, |c| {
                let keys = Column::I64(vec![0, 1, 2, 3]);
                let mask = if c.rank() == 0 {
                    Some(ValidityMask::from_bools(&[false, true, true, true]))
                } else {
                    None
                };
                // scrub to canonical form like the exec layer does
                let mut kvals = keys.clone();
                if let Some(m) = &mask {
                    crate::column::scrub_invalid(&mut kvals, m);
                }
                let vals = Column::F64(vec![1.0; 4]);
                let (kc, outs) = distributed_aggregate_keys(
                    &c,
                    &[(&kvals, mask.as_ref())],
                    &[(&vals, None)],
                    &specs()[..2],
                    strategy,
                    KeyNullability::Runtime,
                )
                .unwrap();
                let mut rows = Vec::new();
                for i in 0..kc[0].len() {
                    rows.push((
                        kc[0].is_valid(i),
                        kc[0].values.as_i64()[i],
                        outs[1].values.as_i64()[i],
                    ));
                }
                rows
            });
            let mut all: Vec<(bool, i64, i64)> = out.into_iter().flatten().collect();
            all.sort();
            // groups: null (1 row from rank 0), 0 (2 rows: ranks 1,2),
            // 1 (2 valid + rank 0's), 2, 3 likewise
            assert_eq!(
                all,
                vec![
                    (false, 0, 1),
                    (true, 0, 2),
                    (true, 1, 3),
                    (true, 2, 3),
                    (true, 3, 3)
                ],
                "strategy {strategy:?}"
            );
        }
    }

    #[test]
    fn distributed_composite_strategies_agree() {
        // keys (i % 3, i % 2 as bool) with value i, over 3 ranks of 8 rows
        let expected_groups = 6usize;
        for strategy in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
            let out = run_spmd(3, |c| {
                let base = (c.rank() * 8) as i64;
                let ids: Vec<i64> = (base..base + 8).collect();
                let k1 = Column::I64(ids.iter().map(|i| i % 3).collect());
                let k2 = Column::Bool(ids.iter().map(|i| i % 2 == 0).collect());
                let vals = Column::F64(ids.iter().map(|&i| i as f64).collect());
                let (kcols, outs) = distributed_aggregate_keys(
                    &c,
                    &[(&k1, None), (&k2, None)],
                    &[(&vals, None)],
                    &specs()[..1],
                    strategy,
                    KeyNullability::Static(false),
                )
                .unwrap();
                (
                    kcols[0].values.as_i64().to_vec(),
                    kcols[1].values.as_bool().to_vec(),
                    outs[0].values.as_f64().to_vec(),
                )
            });
            let mut rows: Vec<(i64, bool, f64)> = out
                .iter()
                .flat_map(|(a, b, s)| {
                    a.iter()
                        .zip(b.iter())
                        .zip(s.iter())
                        .map(|((&a, &b), &s)| (a, b, s))
                })
                .collect();
            rows.sort_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
            assert_eq!(rows.len(), expected_groups, "strategy {strategy:?}");
            // serial oracle
            let mut expect: std::collections::BTreeMap<(i64, bool), f64> = Default::default();
            for i in 0..24i64 {
                *expect.entry((i % 3, i % 2 == 0)).or_insert(0.0) += i as f64;
            }
            let expect: Vec<((i64, bool), f64)> = expect.into_iter().collect();
            for ((a, b, s), (ek, es)) in rows.iter().zip(&expect) {
                assert_eq!((*a, *b), *ek, "strategy {strategy:?}");
                assert!((s - es).abs() < 1e-9, "strategy {strategy:?}: {s} vs {es}");
            }
        }
    }

    #[test]
    fn count_distinct_distributed() {
        let spec = vec![AggSpec {
            func: AggFn::CountDistinct,
            input_dtype: DType::I64,
        }];
        for strategy in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
            let out = run_spmd(2, |c| {
                // key 0 sees values {rank, rank, 7} → distinct {0,1,7} globally
                let keys = vec![0i64, 0, 0];
                let vals = Column::I64(vec![c.rank() as i64, c.rank() as i64, 7]);
                let (k, outs) =
                    distributed_aggregate(&c, &keys, &[vals], &spec, strategy).unwrap();
                (k, outs[0].as_i64().to_vec())
            });
            let all: Vec<(i64, i64)> = out
                .iter()
                .flat_map(|(k, v)| k.iter().zip(v.iter()).map(|(&k, &v)| (k, v)))
                .collect();
            assert_eq!(all, vec![(0, 3)], "strategy {strategy:?}");
        }
    }

    #[test]
    fn min_max_int_preserved() {
        let spec = vec![
            AggSpec {
                func: AggFn::Min,
                input_dtype: DType::I64,
            },
            AggSpec {
                func: AggFn::Max,
                input_dtype: DType::I64,
            },
        ];
        let keys = vec![5i64, 5, 5];
        let vals = Column::I64(vec![3, -2, 9]);
        let (k, outs) = local_hash_aggregate(&keys, &[vals.clone(), vals], &spec);
        assert_eq!(k, vec![5]);
        assert_eq!(outs[0].as_i64(), &[-2]);
        assert_eq!(outs[1].as_i64(), &[9]);
    }

    #[test]
    fn min_over_all_null_group_is_null_not_inf() {
        use crate::column::ValidityMask;
        let spec = vec![AggSpec {
            func: AggFn::Min,
            input_dtype: DType::I64,
        }];
        let k = Column::I64(vec![1, 1]);
        let v = Column::I64(vec![0, 0]);
        let vm = ValidityMask::new_null(2);
        let (kc, outs) =
            local_hash_aggregate_keys(&[(&k, None)], &[(&v, Some(&vm))], &spec).unwrap();
        assert_eq!(kc[0].values.as_i64(), &[1]);
        // the dtype is preserved (no F64 ∞ leak) and the value is NULL
        assert_eq!(outs[0].dtype(), DType::I64);
        assert!(!outs[0].is_valid(0));
        assert_eq!(outs[0].values.as_i64(), &[0]);
    }

    #[test]
    fn empty_input() {
        let (k, outs) = local_hash_aggregate(&[], &[Column::F64(vec![])], &specs()[..1]);
        assert!(k.is_empty());
        assert_eq!(outs[0].len(), 0);
    }
}
