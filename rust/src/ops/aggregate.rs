//! Distributed aggregation (paper §4.5): shuffle rows so equal keys meet on
//! their owner rank, then hash-table aggregation (the paper's
//! `agg1_table[key]` loop in Fig. 5).
//!
//! Two strategies, ablated in `benches/ablations.rs`:
//! * **raw shuffle** — ship `(key, expr values)` rows, aggregate after.
//!   This is exactly the paper's codegen.
//! * **local pre-aggregation** — fold rows into decomposed partial states
//!   ([`AggState`]) per key *before* the shuffle, ship states, merge after.
//!   A classic combiner; wins when keys repeat within ranks (§Perf).

use super::shuffle::{owner_of, shuffle_by_key};
use crate::column::Column;
use crate::comm::Comm;
use crate::expr::{AggFn, AggState};
use crate::types::DType;
use anyhow::Result;
use crate::fxhash::FxHashMap;

/// Which aggregation strategy to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggStrategy {
    RawShuffle,
    PreAggregate,
}

/// One reduction spec: function + dtype of its (already evaluated)
/// expression column.
#[derive(Debug, Clone, Copy)]
pub struct AggSpec {
    pub func: AggFn,
    pub input_dtype: DType,
}

/// Aggregate `expr_cols[i]` under `specs[i]` grouped by `keys`, distributed
/// over `comm`. Returns the local shard of the result: unique keys owned by
/// this rank plus one value column per spec. Output distribution: `1D_VAR`.
pub fn distributed_aggregate(
    comm: &Comm,
    keys: &[i64],
    expr_cols: &[Column],
    specs: &[AggSpec],
    strategy: AggStrategy,
) -> Result<(Vec<i64>, Vec<Column>)> {
    assert_eq!(expr_cols.len(), specs.len());
    match strategy {
        AggStrategy::RawShuffle => {
            let (k, cols) = shuffle_by_key(comm, keys, expr_cols)?;
            Ok(local_hash_aggregate(&k, &cols, specs))
        }
        AggStrategy::PreAggregate => {
            // fold locally into partial states per key
            let mut table: FxHashMap<i64, Vec<AggState>> = FxHashMap::default();
            for (i, &k) in keys.iter().enumerate() {
                let states = table
                    .entry(k)
                    .or_insert_with(|| new_states(specs));
                for (s, c) in states.iter_mut().zip(expr_cols) {
                    s.update_col(c, i);
                }
            }
            // serialize per destination: [key, state0, state1, …] records
            let p = comm.nranks();
            let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
            for (k, states) in &table {
                let buf = &mut bufs[owner_of(*k, p)];
                buf.extend_from_slice(&k.to_le_bytes());
                for s in states {
                    s.encode(buf);
                }
            }
            let received = comm.alltoallv_bytes(bufs);
            // merge incoming partials
            let mut merged: FxHashMap<i64, Vec<AggState>> = FxHashMap::default();
            for buf in received {
                let mut pos = 0;
                while pos < buf.len() {
                    let mut kb = [0u8; 8];
                    kb.copy_from_slice(&buf[pos..pos + 8]);
                    pos += 8;
                    let k = i64::from_le_bytes(kb);
                    let incoming: Vec<AggState> = specs
                        .iter()
                        .map(|sp| AggState::decode(sp.func, sp.input_dtype, &buf, &mut pos))
                        .collect();
                    match merged.entry(k) {
                        std::collections::hash_map::Entry::Occupied(mut e) => {
                            for (a, b) in e.get_mut().iter_mut().zip(&incoming) {
                                a.merge(b);
                            }
                        }
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert(incoming);
                        }
                    }
                }
            }
            Ok(finish_table(merged, specs))
        }
    }
}

/// Purely local hash aggregation (also the post-shuffle half and the serial
/// baseline's implementation).
pub fn local_hash_aggregate(
    keys: &[i64],
    expr_cols: &[Column],
    specs: &[AggSpec],
) -> (Vec<i64>, Vec<Column>) {
    let mut table: FxHashMap<i64, Vec<AggState>> = FxHashMap::default();
    for (i, &k) in keys.iter().enumerate() {
        let states = table.entry(k).or_insert_with(|| new_states(specs));
        for (s, c) in states.iter_mut().zip(expr_cols) {
            s.update_col(c, i);
        }
    }
    finish_table(table, specs)
}

fn new_states(specs: &[AggSpec]) -> Vec<AggState> {
    specs
        .iter()
        .map(|sp| AggState::new(sp.func, sp.input_dtype))
        .collect()
}

fn finish_table(
    table: FxHashMap<i64, Vec<AggState>>,
    specs: &[AggSpec],
) -> (Vec<i64>, Vec<Column>) {
    // deterministic output order (sorted keys) so runs are reproducible
    let mut keys: Vec<i64> = table.keys().copied().collect();
    keys.sort_unstable();
    let mut outs: Vec<Column> = specs
        .iter()
        .map(|sp| {
            Column::new_empty(match (sp.func, sp.input_dtype) {
                (AggFn::Count | AggFn::CountDistinct, _) => DType::I64,
                (AggFn::Mean | AggFn::Var, _) => DType::F64,
                (AggFn::Sum | AggFn::Min | AggFn::Max, DType::I64 | DType::Bool) => DType::I64,
                (AggFn::Sum | AggFn::Min | AggFn::Max, _) => DType::F64,
                (AggFn::First, dt) => dt,
            })
        })
        .collect();
    for k in &keys {
        for (out, state) in outs.iter_mut().zip(&table[k]) {
            out.push(&state.finish());
        }
    }
    (keys, outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;

    fn specs() -> Vec<AggSpec> {
        vec![
            AggSpec {
                func: AggFn::Sum,
                input_dtype: DType::F64,
            },
            AggSpec {
                func: AggFn::Count,
                input_dtype: DType::F64,
            },
            AggSpec {
                func: AggFn::Mean,
                input_dtype: DType::F64,
            },
        ]
    }

    #[test]
    fn local_agg_basics() {
        let keys = vec![1i64, 2, 1, 2, 1];
        let vals = Column::F64(vec![1.0, 10.0, 2.0, 20.0, 3.0]);
        let (k, outs) =
            local_hash_aggregate(&keys, &[vals.clone(), vals.clone(), vals], &specs());
        assert_eq!(k, vec![1, 2]);
        assert_eq!(outs[0].as_f64(), &[6.0, 30.0]);
        assert_eq!(outs[1].as_i64(), &[3, 2]);
        assert_eq!(outs[2].as_f64(), &[2.0, 15.0]);
    }

    #[test]
    fn distributed_strategies_agree() {
        for strategy in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
            let out = run_spmd(3, |c| {
                // every rank holds keys (rank..rank+6) % 4 with value = key
                let keys: Vec<i64> = (0..6).map(|i| ((c.rank() + i) % 4) as i64).collect();
                let vals = Column::F64(keys.iter().map(|&k| k as f64).collect());
                let (k, outs) = distributed_aggregate(
                    &c,
                    &keys,
                    &[vals.clone(), vals.clone(), vals],
                    &specs(),
                    strategy,
                )
                .unwrap();
                (k, outs[0].as_f64().to_vec(), outs[1].as_i64().to_vec())
            });
            // collect global result
            let mut rows: Vec<(i64, f64, i64)> = out
                .iter()
                .flat_map(|(k, s, n)| {
                    k.iter()
                        .zip(s.iter())
                        .zip(n.iter())
                        .map(|((&k, &s), &n)| (k, s, n))
                })
                .collect();
            rows.sort_by_key(|r| r.0);
            // serial oracle over the same global data
            let mut all_keys = Vec::new();
            for r in 0..3usize {
                for i in 0..6usize {
                    all_keys.push(((r + i) % 4) as i64);
                }
            }
            let mut expect: std::collections::BTreeMap<i64, (f64, i64)> = Default::default();
            for &k in &all_keys {
                let e = expect.entry(k).or_insert((0.0, 0));
                e.0 += k as f64;
                e.1 += 1;
            }
            let expect: Vec<(i64, f64, i64)> =
                expect.into_iter().map(|(k, (s, n))| (k, s, n)).collect();
            assert_eq!(rows, expect, "strategy {strategy:?}");
            // each key lives on exactly one rank
            let mut owners = std::collections::HashSet::new();
            for (k, _, _) in &rows {
                assert!(owners.insert(*k), "key {k} appears on two ranks");
            }
        }
    }

    #[test]
    fn count_distinct_distributed() {
        let spec = vec![AggSpec {
            func: AggFn::CountDistinct,
            input_dtype: DType::I64,
        }];
        for strategy in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
            let out = run_spmd(2, |c| {
                // key 0 sees values {rank, rank, 7} → distinct {0,1,7} globally
                let keys = vec![0i64, 0, 0];
                let vals = Column::I64(vec![c.rank() as i64, c.rank() as i64, 7]);
                let (k, outs) =
                    distributed_aggregate(&c, &keys, &[vals], &spec, strategy).unwrap();
                (k, outs[0].as_i64().to_vec())
            });
            let all: Vec<(i64, i64)> = out
                .iter()
                .flat_map(|(k, v)| k.iter().zip(v.iter()).map(|(&k, &v)| (k, v)))
                .collect();
            assert_eq!(all, vec![(0, 3)], "strategy {strategy:?}");
        }
    }

    #[test]
    fn min_max_int_preserved() {
        let spec = vec![
            AggSpec {
                func: AggFn::Min,
                input_dtype: DType::I64,
            },
            AggSpec {
                func: AggFn::Max,
                input_dtype: DType::I64,
            },
        ];
        let keys = vec![5i64, 5, 5];
        let vals = Column::I64(vec![3, -2, 9]);
        let (k, outs) = local_hash_aggregate(&keys, &[vals.clone(), vals], &spec);
        assert_eq!(k, vec![5]);
        assert_eq!(outs[0].as_i64(), &[-2]);
        assert_eq!(outs[1].as_i64(), &[9]);
    }

    #[test]
    fn empty_input() {
        let (k, outs) = local_hash_aggregate(&[], &[Column::F64(vec![])], &specs()[..1]);
        assert!(k.is_empty());
        assert_eq!(outs[0].len(), 0);
    }
}
