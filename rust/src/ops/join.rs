//! Equi-join over composite keys with join types and a skew-aware
//! broadcast path.
//!
//! **Hash path** (the default, [`crate::types::JoinStrategy::Hash`]): both
//! sides are hash-partitioned by their key *tuple* so equal keys meet on
//! `owner_of_key(keys)` (the paper's hash partitioning, Fig. 5, generalized
//! from `_df_id[i] % npes` to an Fx hash over the key list). The local join
//! is a hash join producing `(left, right)` index pairs where a missing
//! side (`None`) marks the null-introduced rows of Left / Right / Outer
//! joins. Because the shuffle colocates equal keys, the unmatched-row
//! bookkeeping is purely rank-local.
//!
//! **Skew path** ([`crate::types::JoinStrategy::SkewBroadcast`]): hash
//! partitioning collapses onto one rank when a few keys dominate the probe
//! side (paper §5.1, the TPCx-BB Q05 imbalance). A distributed sampling
//! pass ([`crate::ops::skew::detect_heavy_hitters`]) agrees on the set of
//! heavy key tuples; rows are then split per side — heavy *probe* (left)
//! rows stay on their home rank un-shuffled, heavy *build* (right) rows are
//! replicated to every rank, light rows of both sides take the ordinary
//! hash shuffle — and the two partial joins are unioned. For Right/Outer
//! joins the replicated build rows' matched flags are OR-merged globally
//! so unmatched build rows are emitted exactly once (on their origin
//! rank). See DESIGN.md §4.3 for the per-join-type argument.
//!
//! The seed's single-key sort-merge join ([`local_sort_merge_join`]) is kept
//! both as the historical reference implementation and as an oracle in the
//! property tests.

use super::keys::{KeyNullability, KeyRow, PackedKeys};
use super::shuffle::{shuffle_by_packed_nullable, shuffle_rows_by_owner_nullable};
use super::skew::{detect_heavy_hitters, HeavySet};
use super::spill::{nullable_bytes, PartitionStore, SpillCtx, MAX_SPILL_DEPTH};
use crate::column::{
    decode_nullable_column, encode_nullable_column_take, extend_opt_mask, normalize_mask,
    Column, NullableColumn, ValidityMask,
};
use crate::comm::Comm;
use crate::fxhash::FxHashMap;
use crate::types::{JoinStrategy, JoinType};
use anyhow::{bail, Result};

/// One column with its optional validity mask — the argument shape of the
/// nullable relational operators.
pub type MaskedCol<'a> = (&'a Column, Option<&'a ValidityMask>);

/// Local sort-merge inner join over single i64 keys (the seed's kernel).
/// Returns `(left_indices, right_indices)` — one entry per output row (the
/// cross product within each equal-key group).
pub fn local_sort_merge_join(lkeys: &[i64], rkeys: &[i64]) -> (Vec<usize>, Vec<usize>) {
    let mut lidx: Vec<usize> = (0..lkeys.len()).collect();
    let mut ridx: Vec<usize> = (0..rkeys.len()).collect();
    lidx.sort_by_key(|&i| lkeys[i]); // stable = Timsort-family
    ridx.sort_by_key(|&i| rkeys[i]);

    let mut out_l = Vec::new();
    let mut out_r = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < lidx.len() && j < ridx.len() {
        let lk = lkeys[lidx[i]];
        let rk = rkeys[ridx[j]];
        if lk < rk {
            i += 1;
        } else if lk > rk {
            j += 1;
        } else {
            // find the extents of the equal-key runs
            let mut ie = i;
            while ie < lidx.len() && lkeys[lidx[ie]] == lk {
                ie += 1;
            }
            let mut je = j;
            while je < ridx.len() && rkeys[ridx[je]] == rk {
                je += 1;
            }
            for &li in &lidx[i..ie] {
                for &rj in &ridx[j..je] {
                    out_l.push(li);
                    out_r.push(rj);
                }
            }
            i = ie;
            j = je;
        }
    }
    (out_l, out_r)
}

/// Local hash join over *packed* key sets with join-type semantics — the
/// HiFrames hot path: the build table maps raw key hashes to candidate right
/// rows and tuple equality against the packed bytes resolves collisions, so
/// no per-row `Vec<KeyVal>` is ever allocated. Pair semantics and output
/// order are identical to [`local_join_pairs`] (the KeyRow reference
/// implementation, kept for the baseline engines and as the oracle in the
/// property tests).
pub fn packed_join_pairs(
    lkeys: &PackedKeys<'_>,
    rkeys: &PackedKeys<'_>,
    how: JoinType,
) -> Vec<(Option<usize>, Option<usize>)> {
    let (mut out, right_matched) = packed_join_pairs_partial(lkeys, rkeys, how);
    if matches!(how, JoinType::Right | JoinType::Outer) {
        for (j, m) in right_matched.iter().enumerate() {
            if !m {
                out.push((None, Some(j)));
            }
        }
    }
    out
}

/// [`packed_join_pairs`] without the trailing unmatched-right emission:
/// returns the pairs built from the left-side probe plus the per-right-row
/// matched flags. The hash path appends the unmatched right rows locally
/// (shuffled keys colocate); the skew path must first OR-merge the flags of
/// the *replicated* build rows across ranks, because any rank may have
/// matched them.
pub fn packed_join_pairs_partial(
    lkeys: &PackedKeys<'_>,
    rkeys: &PackedKeys<'_>,
    how: JoinType,
) -> (Vec<(Option<usize>, Option<usize>)>, Vec<bool>) {
    let mut index: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
    for j in 0..rkeys.len() {
        index.entry(rkeys.hash_row(j)).or_default().push(j as u32);
    }
    let mut out = Vec::new();
    let mut right_matched = vec![false; rkeys.len()];
    for i in 0..lkeys.len() {
        let mut matched = false;
        if let Some(cands) = index.get(&lkeys.hash_row(i)) {
            for &j32 in cands {
                let j = j32 as usize;
                if !lkeys.eq_rows(i, rkeys, j) {
                    continue; // hash collision between distinct tuples
                }
                matched = true;
                match how {
                    // Semi/Anti only need match existence
                    JoinType::Semi | JoinType::Anti => break,
                    _ => {
                        right_matched[j] = true;
                        out.push((Some(i), Some(j)));
                    }
                }
            }
        }
        match (matched, how) {
            (true, JoinType::Semi) => out.push((Some(i), None)),
            (false, JoinType::Left | JoinType::Outer | JoinType::Anti) => {
                out.push((Some(i), None))
            }
            _ => {}
        }
    }
    (out, right_matched)
}

/// Local hash join over key tuples with join-type semantics. Returns one
/// `(left, right)` index pair per output row; `None` marks the missing side
/// of an unmatched row (never both `None`). Left rows are visited in input
/// order; for Right/Outer the unmatched right rows follow in input order.
pub fn local_join_pairs(
    lkeys: &[KeyRow],
    rkeys: &[KeyRow],
    how: JoinType,
) -> Vec<(Option<usize>, Option<usize>)> {
    let mut index: FxHashMap<&KeyRow, Vec<usize>> = FxHashMap::default();
    for (j, k) in rkeys.iter().enumerate() {
        index.entry(k).or_default().push(j);
    }
    let mut out = Vec::new();
    let mut right_matched = vec![false; rkeys.len()];
    for (i, k) in lkeys.iter().enumerate() {
        match index.get(k) {
            Some(matches) => match how {
                JoinType::Anti => {}
                JoinType::Semi => out.push((Some(i), None)),
                _ => {
                    for &j in matches {
                        right_matched[j] = true;
                        out.push((Some(i), Some(j)));
                    }
                }
            },
            None => match how {
                JoinType::Left | JoinType::Outer => out.push((Some(i), None)),
                JoinType::Anti => out.push((Some(i), None)),
                JoinType::Inner | JoinType::Right | JoinType::Semi => {}
            },
        }
    }
    if matches!(how, JoinType::Right | JoinType::Outer) {
        for (j, m) in right_matched.iter().enumerate() {
            if !m {
                out.push((None, Some(j)));
            }
        }
    }
    out
}

/// Distributed equi-join over composite keys with validity masks.
///
/// `lkeys`/`rkeys` are the key columns (with optional masks) in `on`-pair
/// order (equal dtypes per pair, validated by plan typing); `lpay`/`rpay`
/// the non-key payload columns. Null keys are ordinary key values (null
/// matches null — the Pandas merge rule), routed/compared through the
/// validity-flagged packed layout; the flag choice is agreed globally so
/// equal keys colocate no matter which rank holds a mask. Returns:
///
/// * one output key column per pair (key dtype preserved; value and
///   validity from whichever side is present);
/// * the left payload columns (dtype preserved; unmatched rows get cleared
///   validity bits when `how.nullable_left()`);
/// * the right payload columns (empty for Semi/Anti, null-introduced when
///   `how.nullable_right()`).
///
/// Output distribution is `1D_VAR`.
pub fn distributed_join_on(
    comm: &Comm,
    lkeys: &[MaskedCol],
    lpay: &[MaskedCol],
    rkeys: &[MaskedCol],
    rpay: &[MaskedCol],
    how: JoinType,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>, Vec<NullableColumn>)> {
    distributed_join_on_strategy(
        comm,
        lkeys,
        lpay,
        rkeys,
        rpay,
        how,
        JoinStrategy::Hash,
        KeyNullability::Runtime,
    )
}

/// [`distributed_join_on`] with an explicit [`JoinStrategy`].
///
/// `JoinStrategy::Hash` is the plain hash-partitioned join. With
/// `JoinStrategy::SkewBroadcast { .. }` a sampling pass first agrees on the
/// heavy-hitter key set (see [`crate::ops::skew`]); if none is found the
/// join degrades to the hash path at the cost of one allgather, otherwise
/// rows split into a shuffled light partition and a broadcast heavy
/// partition whose results are unioned. Output multisets are identical for
/// both strategies; only the routing (and therefore the per-rank row
/// distribution of the `1D_VAR` output) differs.
#[allow(clippy::too_many_arguments)]
pub fn distributed_join_on_strategy(
    comm: &Comm,
    lkeys: &[MaskedCol],
    lpay: &[MaskedCol],
    rkeys: &[MaskedCol],
    rpay: &[MaskedCol],
    how: JoinType,
    strategy: JoinStrategy,
    nullability: KeyNullability,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>, Vec<NullableColumn>)> {
    distributed_join_on_budgeted(
        comm,
        lkeys,
        lpay,
        rkeys,
        rpay,
        how,
        strategy,
        nullability,
        &SpillCtx::unlimited(),
    )
}

/// [`distributed_join_on_strategy`] under a per-rank memory budget. When
/// the post-shuffle build side exceeds `spill`'s budget, the local join
/// becomes a grace hash join: both sides are hash-partitioned to disk
/// (level-salted so recursion splits along fresh boundaries), partitions
/// are joined one at a time, and oversized partitions recurse up to
/// [`MAX_SPILL_DEPTH`]. The output is byte-identical to the in-memory
/// path for every join type — see `grace_join_pairs` for the argument.
#[allow(clippy::too_many_arguments)]
pub fn distributed_join_on_budgeted(
    comm: &Comm,
    lkeys: &[MaskedCol],
    lpay: &[MaskedCol],
    rkeys: &[MaskedCol],
    rpay: &[MaskedCol],
    how: JoinType,
    strategy: JoinStrategy,
    nullability: KeyNullability,
    spill: &SpillCtx,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>, Vec<NullableColumn>)> {
    if lkeys.len() != rkeys.len() || lkeys.is_empty() {
        bail!("join: key column lists must be non-empty and equal length");
    }
    let nk = lkeys.len();
    // every rank (and both sides) must agree on the flagged-vs-plain key
    // layout, or the hash routing would split equal keys across ranks;
    // statically typed plans resolve this from the schema with no collective
    let local_flag = lkeys.iter().chain(rkeys).any(|(_, m)| m.is_some());
    let with_flags = nullability.with_flags(comm, local_flag);

    fn split<'a>(
        side: &[MaskedCol<'a>],
    ) -> (Vec<&'a Column>, Vec<Option<&'a ValidityMask>>) {
        (
            side.iter().map(|(c, _)| *c).collect(),
            side.iter().map(|(_, m)| *m).collect(),
        )
    }
    let (lkc, lkm) = split(lkeys);
    let (rkc, rkm) = split(rkeys);
    let lpacked_pre = PackedKeys::pack_masked(&lkc, &lkm, with_flags)?;
    let rpacked_pre = PackedKeys::pack_masked(&rkc, &rkm, with_flags)?;

    // all columns (keys first), as references — no clones into the shuffle
    let mut lall: Vec<&Column> = lkc.clone();
    let mut lmasks: Vec<Option<&ValidityMask>> = lkm.clone();
    for (c, m) in lpay {
        lall.push(c);
        lmasks.push(*m);
    }
    let mut rall: Vec<&Column> = rkc.clone();
    let mut rmasks: Vec<Option<&ValidityMask>> = rkm.clone();
    for (c, m) in rpay {
        rall.push(c);
        rmasks.push(*m);
    }

    // heavy-hitter detection (skew strategy only). The detected set is
    // identical on every rank, so every rank takes the same branch below —
    // the collective schedules stay aligned. A single-rank world skips
    // straight to the local hash join: there is no imbalance to fix, and
    // the sampling/replication machinery would be pure overhead.
    let heavy = match strategy.threshold() {
        Some(threshold) if comm.nranks() > 1 => {
            detect_heavy_hitters(comm, &lpacked_pre, threshold)
        }
        _ => HeavySet::empty(),
    };

    if heavy.is_empty() {
        // ---- plain hash path: shuffle everything, join locally ----
        let (lcols, lms) =
            shuffle_by_packed_nullable(comm, &lpacked_pre, &lall, &lmasks)?;
        let (rcols, rms) =
            shuffle_by_packed_nullable(comm, &rpacked_pre, &rall, &rmasks)?;
        let (pairs, _) = join_partition(nk, &lcols, &lms, &rcols, &rms, how, true, spill)?;
        return Ok(assemble_outputs(nk, &lcols, &lms, &rcols, &rms, &pairs, how));
    }

    // ---- skew path ----
    let p = comm.nranks();
    let (lheavy_idx, llight_idx) = partition_heavy(&heavy, &lpacked_pre);
    let (rheavy_idx, rlight_idx) = partition_heavy(&heavy, &rpacked_pre);

    // light rows of both sides: the ordinary hash shuffle (owners from the
    // globally agreed pre-shuffle packing, so equal light keys colocate)
    let llight_owners: Vec<usize> =
        llight_idx.iter().map(|&i| lpacked_pre.owner(i, p)).collect();
    let rlight_owners: Vec<usize> =
        rlight_idx.iter().map(|&i| rpacked_pre.owner(i, p)).collect();
    let (l1, lm1) =
        shuffle_rows_by_owner_nullable(comm, &llight_owners, &llight_idx, &lall, &lmasks)?;
    let (r1, rm1) =
        shuffle_rows_by_owner_nullable(comm, &rlight_owners, &rlight_idx, &rall, &rmasks)?;
    let (pairs1, _) = join_partition(nk, &l1, &lm1, &r1, &rm1, how, true, spill)?;
    let (k1, lo1, ro1) = assemble_outputs(nk, &l1, &lm1, &r1, &rm1, &pairs1, how);

    // heavy partition: probe rows stay local (they are already spread over
    // the ranks by the input distribution — that *is* the load balancing),
    // build rows replicate to every rank so each local probe sees the full
    // matching set
    let (l2, lm2) = take_rows(&lall, &lmasks, &lheavy_idx);
    let (r2, rm2, my_start) = replicate_rows(comm, &rall, &rmasks, &rheavy_idx)?;
    let (mut pairs2, right_matched) =
        join_partition(nk, &l2, &lm2, &r2, &rm2, how, false, spill)?;
    if matches!(how, JoinType::Right | JoinType::Outer) {
        // a replicated build row may be matched on any rank: OR-merge the
        // flags and emit each globally-unmatched row exactly once, on the
        // rank that originally contributed it
        let flags: Vec<u8> = right_matched.iter().map(|&b| b as u8).collect();
        let global = comm.allreduce_bytes_or(flags);
        for j in my_start..my_start + rheavy_idx.len() {
            if global[j] == 0 {
                pairs2.push((None, Some(j)));
            }
        }
    }
    let (k2, lo2, ro2) = assemble_outputs(nk, &l2, &lm2, &r2, &rm2, &pairs2, how);

    // union of the two partitions (light first, then heavy)
    let keys_out = k1
        .into_iter()
        .zip(k2)
        .map(|(a, b)| concat_nullable(a, &b))
        .collect();
    let left_out = lo1
        .into_iter()
        .zip(lo2)
        .map(|(a, b)| concat_nullable(a, &b))
        .collect();
    let right_out = ro1
        .into_iter()
        .zip(ro2)
        .map(|(a, b)| concat_nullable(a, &b))
        .collect();
    Ok((keys_out, left_out, right_out))
}

/// Split row indices of a packed key set into `(heavy, light)` by heavy-set
/// membership, preserving row order within each partition.
fn partition_heavy(heavy: &HeavySet, keys: &PackedKeys) -> (Vec<usize>, Vec<usize>) {
    let mut h = Vec::new();
    let mut l = Vec::new();
    for i in 0..keys.len() {
        if heavy.contains(keys, i) {
            h.push(i);
        } else {
            l.push(i);
        }
    }
    (h, l)
}

/// Gather the `idx` rows of every column (and its mask) into owned columns.
fn take_rows(
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
    idx: &[usize],
) -> (Vec<Column>, Vec<Option<ValidityMask>>) {
    let out_cols: Vec<Column> = cols.iter().map(|c| c.take(idx)).collect();
    let out_masks: Vec<Option<ValidityMask>> = masks
        .iter()
        .map(|m| normalize_mask((*m).map(|vm| vm.take(idx))))
        .collect();
    (out_cols, out_masks)
}

/// Replicate the `idx` rows of every column to all ranks (one allgather of
/// the nullable column framing). Returns the replicated columns/masks —
/// identical on every rank, source chunks concatenated in rank order — and
/// the row offset where this rank's own contribution starts (its rows span
/// `my_start..my_start + idx.len()`).
fn replicate_rows(
    comm: &Comm,
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
    idx: &[usize],
) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>, usize)> {
    let mut buf = Vec::new();
    for (&c, &m) in cols.iter().zip(masks.iter()) {
        encode_nullable_column_take(c, m, idx, &mut buf);
    }
    let chunks = comm.allgather_bytes(buf);
    let mut out_cols: Vec<Column> =
        cols.iter().map(|c| Column::new_empty(c.dtype())).collect();
    let mut out_masks: Vec<Option<ValidityMask>> = vec![None; cols.len()];
    let mut my_start = 0usize;
    for (r, chunk) in chunks.iter().enumerate() {
        let mut pos = 0usize;
        let mut chunk_rows = 0usize;
        for (oc, om) in out_cols.iter_mut().zip(out_masks.iter_mut()) {
            let before = oc.len();
            let (c, m) = decode_nullable_column(chunk, &mut pos)?;
            chunk_rows = c.len();
            oc.extend(&c);
            extend_opt_mask(om, before, m.as_ref(), c.len());
        }
        if r < comm.rank() {
            my_start += chunk_rows;
        }
    }
    Ok((out_cols, out_masks, my_start))
}

/// Pack the local key columns of both sides (first `nk` columns, with a
/// locally agreed flag layout) and run the packed hash join. With
/// `emit_right_unmatched`, Right/Outer append their locally-unmatched right
/// rows — correct whenever the two sides' equal keys are fully colocated
/// (the hash path and the light partition); the heavy partition passes
/// `false` and resolves unmatched build rows globally instead.
#[allow(clippy::too_many_arguments)]
pub(crate) fn join_partition(
    nk: usize,
    lcols: &[Column],
    lmasks: &[Option<ValidityMask>],
    rcols: &[Column],
    rmasks: &[Option<ValidityMask>],
    how: JoinType,
    emit_right_unmatched: bool,
    spill: &SpillCtx,
) -> Result<(Vec<(Option<usize>, Option<usize>)>, Vec<bool>)> {
    // post-routing: only the two local sides must agree on the layout
    let flags = lmasks[..nk]
        .iter()
        .chain(&rmasks[..nk])
        .any(|m| m.is_some());
    let build_bytes = nullable_bytes(rcols, rmasks);
    let (mut pairs, right_matched) = if spill.should_spill(build_bytes) {
        grace_join_pairs(nk, lcols, lmasks, rcols, rmasks, how, flags, spill, 0)?
    } else {
        let lpacked = pack_key_prefix(lcols, lmasks, nk, flags)?;
        let rpacked = pack_key_prefix(rcols, rmasks, nk, flags)?;
        packed_join_pairs_partial(&lpacked, &rpacked, how)
    };
    if emit_right_unmatched && matches!(how, JoinType::Right | JoinType::Outer) {
        for (j, m) in right_matched.iter().enumerate() {
            if !m {
                pairs.push((None, Some(j)));
            }
        }
    }
    Ok((pairs, right_matched))
}

/// Pack the first `nk` columns (the keys) with an explicit flag layout.
fn pack_key_prefix<'a>(
    cols: &'a [Column],
    masks: &'a [Option<ValidityMask>],
    nk: usize,
    flags: bool,
) -> Result<PackedKeys<'a>> {
    let krefs: Vec<&Column> = cols[..nk].iter().collect();
    let km: Vec<Option<&ValidityMask>> = masks[..nk].iter().map(|m| m.as_ref()).collect();
    PackedKeys::pack_masked(&krefs, &km, flags)
}

/// Grace hash join of one colocated partition whose build side exceeds the
/// memory budget: hash-partition both sides to disk on the key hash
/// ([`super::spill::part_of`], salted by `level`), join partition at a
/// time, and recurse on partitions that are still oversized (duplicate
/// keys can defeat partitioning, so recursion stops at [`MAX_SPILL_DEPTH`]
/// or when a partition stops shrinking).
///
/// Returns the same `(probe pairs, right_matched)` contract as
/// [`packed_join_pairs_partial`], **byte-identical** to it:
///
/// * Equal key tuples have equal hashes, so every match lives inside one
///   partition; the per-partition joins find exactly the global match set,
///   and Semi/Anti first-match semantics are local to a partition.
/// * The in-memory probe emits pairs sorted by `(left, right)` — probe
///   rows ascending, and for one probe row its matches ascending (the
///   build index lists candidates in insertion order) — with at most one
///   `(Some(i), None)` per probe row and never both forms for one `i`.
///   Mapping each partition's pairs back through its spilled original-row
///   indices and sorting by `(left, right)` therefore reproduces the
///   in-memory emission exactly.
/// * `right_matched` is the union of the per-partition flags mapped the
///   same way (Semi/Anti never set them, matching the in-memory path).
#[allow(clippy::too_many_arguments)]
fn grace_join_pairs(
    nk: usize,
    lcols: &[Column],
    lmasks: &[Option<ValidityMask>],
    rcols: &[Column],
    rmasks: &[Option<ValidityMask>],
    how: JoinType,
    flags: bool,
    spill: &SpillCtx,
    level: u32,
) -> Result<(Vec<(Option<usize>, Option<usize>)>, Vec<bool>)> {
    let ln = lcols.first().map_or(0, |c| c.len());
    let rn = rcols.first().map_or(0, |c| c.len());
    let lpacked = pack_key_prefix(lcols, lmasks, nk, flags)?;
    let rpacked = pack_key_prefix(rcols, rmasks, nk, flags)?;
    let lhashes: Vec<u64> = (0..ln).map(|i| lpacked.hash_row(i)).collect();
    let rhashes: Vec<u64> = (0..rn).map(|j| rpacked.hash_row(j)).collect();
    drop(lpacked);
    drop(rpacked);

    let nparts = spill.budget().partition_count(nullable_bytes(rcols, rmasks));
    // Spill each side's columns plus one synthetic I64 column holding the
    // original row index, so partition-local pairs map back exactly.
    let lid = Column::I64((0..ln as i64).collect());
    let rid = Column::I64((0..rn as i64).collect());
    let mut lset: Vec<MaskedCol> = lcols.iter().zip(lmasks).map(|(c, m)| (c, m.as_ref())).collect();
    lset.push((&lid, None));
    let mut rset: Vec<MaskedCol> = rcols.iter().zip(rmasks).map(|(c, m)| (c, m.as_ref())).collect();
    rset.push((&rid, None));
    let mut lstore = PartitionStore::partition(spill, "join-probe", nparts, level, &lhashes, &lset)?;
    let mut rstore = PartitionStore::partition(spill, "join-build", nparts, level, &rhashes, &rset)?;

    let mut pairs: Vec<(Option<usize>, Option<usize>)> = Vec::new();
    let mut right_matched = vec![false; rn];
    for p in 0..nparts {
        if lstore.part_rows(p) == 0 && rstore.part_rows(p) == 0 {
            continue;
        }
        let (mut lp, mut lpm) = lstore.read_part(p)?;
        let (mut rp, mut rpm) = rstore.read_part(p)?;
        let lmap = pop_index_column(&mut lp, &mut lpm);
        let rmap = pop_index_column(&mut rp, &mut rpm);
        spill.record_merge_pass();

        let recurse = level + 1 < MAX_SPILL_DEPTH
            && rmap.len() < rn
            && spill.should_spill(nullable_bytes(&rp, &rpm));
        let (ppairs, pmatched) = if recurse {
            grace_join_pairs(nk, &lp, &lpm, &rp, &rpm, how, flags, spill, level + 1)?
        } else {
            let lpk = pack_key_prefix(&lp, &lpm, nk, flags)?;
            let rpk = pack_key_prefix(&rp, &rpm, nk, flags)?;
            packed_join_pairs_partial(&lpk, &rpk, how)
        };
        for (lo, ro) in ppairs {
            pairs.push((lo.map(|i| lmap[i]), ro.map(|j| rmap[j])));
        }
        for (j, m) in pmatched.iter().enumerate() {
            if *m {
                right_matched[rmap[j]] = true;
            }
        }
    }
    // Reconstruct the in-memory probe emission order (see doc comment):
    // `(Option<usize>, Option<usize>)` tuple order IS that order.
    pairs.sort_unstable();
    Ok((pairs, right_matched))
}

/// Detach the trailing synthetic row-index column written by
/// [`grace_join_pairs`]'s spill pass.
fn pop_index_column(cols: &mut Vec<Column>, masks: &mut Vec<Option<ValidityMask>>) -> Vec<usize> {
    masks.pop();
    match cols.pop() {
        Some(Column::I64(v)) => v.into_iter().map(|x| x as usize).collect(),
        other => unreachable!("spill index column missing: {other:?}"),
    }
}

/// Build the join's output columns from its `(left, right)` index pairs:
/// one merged key column per pair (value *and* validity from whichever side
/// is present), then the left payload, then — unless the join type drops
/// them — the right payload, null-introducing the missing side per `how`.
pub(crate) fn assemble_outputs(
    nk: usize,
    lcols: &[Column],
    lmasks: &[Option<ValidityMask>],
    rcols: &[Column],
    rmasks: &[Option<ValidityMask>],
    pairs: &[(Option<usize>, Option<usize>)],
    how: JoinType,
) -> (Vec<NullableColumn>, Vec<NullableColumn>, Vec<NullableColumn>) {
    let (lk, lc) = lcols.split_at(nk);
    let (lkm, lcm) = lmasks.split_at(nk);
    let (rk, rc) = rcols.split_at(nk);
    let (rkm, rcm) = rmasks.split_at(nk);

    let keys_out: Vec<NullableColumn> = (0..nk)
        .map(|j| {
            take_merged(
                (&lk[j], lkm[j].as_ref()),
                (&rk[j], rkm[j].as_ref()),
                pairs,
            )
        })
        .collect();

    let lidx: Vec<Option<usize>> = pairs.iter().map(|&(lo, _)| lo).collect();
    let left_out: Vec<NullableColumn> = if how.nullable_left() {
        lc.iter()
            .zip(lcm)
            .map(|(c, m)| c.take_opt_masked(m.as_ref(), &lidx))
            .collect()
    } else {
        let li: Vec<usize> = lidx.iter().map(|o| o.expect("left index")).collect();
        lc.iter()
            .zip(lcm)
            .map(|(c, m)| {
                NullableColumn::new(c.take(&li), m.as_ref().map(|m| m.take(&li)))
            })
            .collect()
    };

    let right_out: Vec<NullableColumn> = if !how.keeps_right_columns() {
        Vec::new()
    } else {
        let ridx: Vec<Option<usize>> = pairs.iter().map(|&(_, ro)| ro).collect();
        if how.nullable_right() {
            rc.iter()
                .zip(rcm)
                .map(|(c, m)| c.take_opt_masked(m.as_ref(), &ridx))
                .collect()
        } else {
            let ri: Vec<usize> = ridx.iter().map(|o| o.expect("right index")).collect();
            rc.iter()
                .zip(rcm)
                .map(|(c, m)| {
                    NullableColumn::new(c.take(&ri), m.as_ref().map(|m| m.take(&ri)))
                })
                .collect()
        }
    };
    (keys_out, left_out, right_out)
}

/// Append `b`'s rows to `a` (values and validity) — the partition union of
/// the skew path and of the spill operators' partition-at-a-time merges.
pub(crate) fn concat_nullable(a: NullableColumn, b: &NullableColumn) -> NullableColumn {
    let NullableColumn {
        mut values,
        validity,
    } = a;
    let before = values.len();
    let mut mask = validity;
    values.extend(&b.values);
    extend_opt_mask(&mut mask, before, b.validity.as_ref(), b.values.len());
    NullableColumn::new(values, mask)
}

/// Gather one output key column from a join's `(left, right)` index pairs:
/// each output row takes the key cell (value *and* validity bit) from
/// whichever side is present. Both columns have the key dtype (validated by
/// plan typing), so the output dtype is preserved.
fn take_merged(
    left: MaskedCol,
    right: MaskedCol,
    pairs: &[(Option<usize>, Option<usize>)],
) -> NullableColumn {
    fn pick<'v, T>(a: &'v [T], b: &'v [T], lo: Option<usize>, ro: Option<usize>) -> &'v T {
        match (lo, ro) {
            (Some(i), _) => &a[i],
            (None, Some(j)) => &b[j],
            (None, None) => unreachable!("join pair with no sides"),
        }
    }
    let (lcol, lmask) = left;
    let (rcol, rmask) = right;
    let values = match (lcol, rcol) {
        (Column::I64(a), Column::I64(b)) => Column::I64(
            pairs
                .iter()
                .map(|&(lo, ro)| *pick(a, b, lo, ro))
                .collect(),
        ),
        (Column::Bool(a), Column::Bool(b)) => Column::Bool(
            pairs
                .iter()
                .map(|&(lo, ro)| *pick(a, b, lo, ro))
                .collect(),
        ),
        (Column::Str(a), Column::Str(b)) => Column::Str(
            pairs
                .iter()
                .map(|&(lo, ro)| pick(a, b, lo, ro).clone())
                .collect(),
        ),
        (a, b) => panic!(
            "join key dtype mismatch: {:?} vs {:?}",
            a.dtype(),
            b.dtype()
        ),
    };
    let validity = if lmask.is_some() || rmask.is_some() {
        let mut m = ValidityMask::new_null(pairs.len());
        for (o, &(lo, ro)) in pairs.iter().enumerate() {
            let ok = match (lo, ro) {
                (Some(i), _) => lmask.map_or(true, |m| m.get(i)),
                (None, Some(j)) => rmask.map_or(true, |m| m.get(j)),
                (None, None) => unreachable!("join pair with no sides"),
            };
            if ok {
                m.set(o, true);
            }
        }
        Some(m)
    } else {
        None
    };
    NullableColumn::new(values, validity)
}

/// Borrowed masked views over plain columns (no masks) — adapter for
/// mask-free call sites.
pub fn plain<'a>(cols: &[&'a Column]) -> Vec<MaskedCol<'a>> {
    cols.iter().map(|&c| (c, None)).collect()
}

/// Distributed inner equi-join over single i64 keys — the seed API, now a
/// thin wrapper over [`distributed_join_on`]. Output columns: joined key,
/// then left payload columns, then right payload columns.
pub fn distributed_join(
    comm: &Comm,
    lkeys: &[i64],
    lcols: &[Column],
    rkeys: &[i64],
    rcols: &[Column],
) -> Result<(Vec<i64>, Vec<Column>, Vec<Column>)> {
    let lkc = Column::I64(lkeys.to_vec());
    let rkc = Column::I64(rkeys.to_vec());
    let lrefs: Vec<MaskedCol> = lcols.iter().map(|c| (c, None)).collect();
    let rrefs: Vec<MaskedCol> = rcols.iter().map(|c| (c, None)).collect();
    let (keys, lout, rout) = distributed_join_on(
        comm,
        &[(&lkc, None)],
        &lrefs,
        &[(&rkc, None)],
        &rrefs,
        JoinType::Inner,
    )?;
    Ok((
        keys[0].values.as_i64().to_vec(),
        lout.into_iter().map(|c| c.values).collect(),
        rout.into_iter().map(|c| c.values).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;
    use crate::ops::keys::KeyVal;

    /// Brute-force oracle.
    fn nested_loop(lk: &[i64], rk: &[i64]) -> Vec<(i64, usize, usize)> {
        let mut out = Vec::new();
        for (i, &a) in lk.iter().enumerate() {
            for (j, &b) in rk.iter().enumerate() {
                if a == b {
                    out.push((a, i, j));
                }
            }
        }
        out.sort();
        out
    }

    fn rows1(ks: &[i64]) -> Vec<KeyRow> {
        ks.iter().map(|&k| vec![KeyVal::I64(k)]).collect()
    }

    #[test]
    fn local_join_matches_oracle() {
        let lk = vec![3i64, 1, 2, 3, 3];
        let rk = vec![3i64, 3, 5, 1];
        let (li, ri) = local_sort_merge_join(&lk, &rk);
        let mut got: Vec<(i64, usize, usize)> = li
            .iter()
            .zip(&ri)
            .map(|(&i, &j)| (lk[i], i, j))
            .collect();
        got.sort();
        assert_eq!(got, nested_loop(&lk, &rk));
        // 3 appears 3×2 = 6 times, 1 appears 1×1
        assert_eq!(li.len(), 7);

        // the composite hash join agrees with the sort-merge oracle on Inner
        let pairs = local_join_pairs(&rows1(&lk), &rows1(&rk), JoinType::Inner);
        let mut got2: Vec<(i64, usize, usize)> = pairs
            .iter()
            .map(|&(l, r)| (lk[l.unwrap()], l.unwrap(), r.unwrap()))
            .collect();
        got2.sort();
        assert_eq!(got2, nested_loop(&lk, &rk));
    }

    #[test]
    fn local_join_empty_sides() {
        let (li, ri) = local_sort_merge_join(&[], &[1, 2]);
        assert!(li.is_empty() && ri.is_empty());
        let (li, _) = local_sort_merge_join(&[1], &[]);
        assert!(li.is_empty());
        assert!(local_join_pairs(&[], &rows1(&[1, 2]), JoinType::Inner).is_empty());
        assert_eq!(
            local_join_pairs(&rows1(&[1]), &[], JoinType::Left),
            vec![(Some(0), None)]
        );
    }

    #[test]
    fn local_join_no_matches() {
        let (li, _) = local_sort_merge_join(&[1, 2], &[3, 4]);
        assert!(li.is_empty());
    }

    #[test]
    fn local_join_types_semantics() {
        let lk = rows1(&[1, 2, 2, 5]);
        let rk = rows1(&[2, 3]);
        // Inner: two (2,2) matches
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Inner),
            vec![(Some(1), Some(0)), (Some(2), Some(0))]
        );
        // Left: unmatched 1 and 5 survive with None right
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Left),
            vec![
                (Some(0), None),
                (Some(1), Some(0)),
                (Some(2), Some(0)),
                (Some(3), None)
            ]
        );
        // Right: unmatched 3 survives with None left, appended after
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Right),
            vec![(Some(1), Some(0)), (Some(2), Some(0)), (None, Some(1))]
        );
        // Outer = Left ∪ unmatched right
        let outer = local_join_pairs(&lk, &rk, JoinType::Outer);
        assert_eq!(outer.len(), 5);
        assert!(outer.contains(&(None, Some(1))));
        // Semi: one row per matching left row
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Semi),
            vec![(Some(1), None), (Some(2), None)]
        );
        // Anti: the non-matching left rows
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Anti),
            vec![(Some(0), None), (Some(3), None)]
        );
    }

    #[test]
    fn packed_join_matches_keyrow_oracle_all_types() {
        use crate::ops::keys::key_rows;
        // duplicate keys on both sides, unmatched rows on both sides
        let lk1 = Column::I64(vec![1, 2, 2, 5, 7, 2]);
        let lk2 = Column::Bool(vec![true, false, false, true, false, true]);
        let rk1 = Column::I64(vec![2, 3, 2, 7]);
        let rk2 = Column::Bool(vec![false, true, false, true]);
        let lrows = key_rows(&[&lk1, &lk2]).unwrap();
        let rrows = key_rows(&[&rk1, &rk2]).unwrap();
        let lp = PackedKeys::pack(&[&lk1, &lk2]).unwrap();
        let rp = PackedKeys::pack(&[&rk1, &rk2]).unwrap();
        for how in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::Outer,
            JoinType::Semi,
            JoinType::Anti,
        ] {
            assert_eq!(
                packed_join_pairs(&lp, &rp, how),
                local_join_pairs(&lrows, &rrows, how),
                "{how:?}"
            );
        }
        // single-i64 (zero-copy layout) as well
        let a = Column::I64(vec![3, 1, 3, 9]);
        let b = Column::I64(vec![3, 4]);
        let pa = PackedKeys::pack(&[&a]).unwrap();
        let pb = PackedKeys::pack(&[&b]).unwrap();
        for how in [JoinType::Inner, JoinType::Outer, JoinType::Anti] {
            assert_eq!(
                packed_join_pairs(&pa, &pb, how),
                local_join_pairs(
                    &rows1(a.as_i64()),
                    &rows1(b.as_i64()),
                    how
                ),
                "{how:?}"
            );
        }
    }

    #[test]
    fn local_join_composite_keys() {
        let lk = vec![
            vec![KeyVal::I64(1), KeyVal::Str("a".into())],
            vec![KeyVal::I64(1), KeyVal::Str("b".into())],
        ];
        let rk = vec![vec![KeyVal::I64(1), KeyVal::Str("a".into())]];
        // only the full tuple (1,"a") matches — single-column equality is
        // not enough
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Inner),
            vec![(Some(0), Some(0))]
        );
    }

    #[test]
    fn distributed_join_matches_serial() {
        // global data split over 3 ranks
        let lk_all: Vec<i64> = vec![1, 2, 3, 4, 5, 6, 2, 3];
        let rk_all: Vec<i64> = vec![2, 2, 3, 9];
        let out = run_spmd(3, |c| {
            let (ls, ll) = crate::comm::block_range(lk_all.len(), 3, c.rank());
            let (rs, rl) = crate::comm::block_range(rk_all.len(), 3, c.rank());
            let lk = &lk_all[ls..ls + ll];
            let rk = &rk_all[rs..rs + rl];
            let lvals = Column::I64(lk.iter().map(|&k| k * 10).collect());
            let rvals = Column::I64(rk.iter().map(|&k| k * 100).collect());
            let (keys, lc, rc) =
                distributed_join(&c, lk, &[lvals], rk, &[rvals]).unwrap();
            (keys, lc[0].as_i64().to_vec(), rc[0].as_i64().to_vec())
        });
        let mut rows: Vec<(i64, i64, i64)> = out
            .iter()
            .flat_map(|(k, l, r)| {
                k.iter()
                    .zip(l.iter())
                    .zip(r.iter())
                    .map(|((&k, &l), &r)| (k, l, r))
            })
            .collect();
        rows.sort();
        // serial expectation: key 2 matches 2×2=4 rows, key 3 matches 2×1=2
        let expect: Vec<(i64, i64, i64)> = vec![
            (2, 20, 200),
            (2, 20, 200),
            (2, 20, 200),
            (2, 20, 200),
            (3, 30, 300),
            (3, 30, 300),
        ];
        assert_eq!(rows, expect);
        // payload invariants: l = 10k, r = 100k
        for (k, l, r) in rows {
            assert_eq!(l, k * 10);
            assert_eq!(r, k * 100);
        }
    }

    #[test]
    fn distributed_left_join_masks_unmatched() {
        // left keys 0..6 over 2 ranks; right covers only even keys
        let lk_all: Vec<i64> = (0..6).collect();
        let rk_all: Vec<i64> = vec![0, 2, 4];
        let out = run_spmd(2, |c| {
            let (ls, ll) = crate::comm::block_range(lk_all.len(), 2, c.rank());
            let (rs, rl) = crate::comm::block_range(rk_all.len(), 2, c.rank());
            let lkc = Column::I64(lk_all[ls..ls + ll].to_vec());
            let lval = Column::I64(lk_all[ls..ls + ll].iter().map(|k| k + 100).collect());
            let rkc = Column::I64(rk_all[rs..rs + rl].to_vec());
            let rval = Column::I64(rk_all[rs..rs + rl].iter().map(|k| k + 200).collect());
            let (keys, lc, rc) = distributed_join_on(
                &c,
                &[(&lkc, None)],
                &[(&lval, None)],
                &[(&rkc, None)],
                &[(&rval, None)],
                JoinType::Left,
            )
            .unwrap();
            // the right payload keeps its Int64 dtype — nulls live in the mask
            assert_eq!(rc[0].dtype(), crate::types::DType::I64);
            assert!(lc[0].validity.is_none(), "left side of a left join never null");
            (
                keys[0].values.as_i64().to_vec(),
                lc[0].values.as_i64().to_vec(),
                rc[0].values.as_i64().to_vec(),
                (0..rc[0].len()).map(|i| rc[0].is_valid(i)).collect::<Vec<_>>(),
            )
        });
        let mut rows: Vec<(i64, i64, i64, bool)> = out
            .iter()
            .flat_map(|(k, l, r, v)| {
                k.iter()
                    .zip(l.iter())
                    .zip(r.iter().zip(v.iter()))
                    .map(|((&k, &l), (&r, &v))| (k, l, r, v))
            })
            .collect();
        rows.sort();
        assert_eq!(rows.len(), 6); // every left row survives
        for (k, l, r, valid) in &rows {
            assert_eq!(*l, k + 100);
            if k % 2 == 0 {
                assert!(valid, "matched row {k} must be valid");
                assert_eq!(*r, k + 200);
            } else {
                assert!(!valid, "unmatched row {k} must be null");
                assert_eq!(*r, 0, "null lanes hold the dtype default");
            }
        }
    }

    #[test]
    fn distributed_join_on_nullable_keys_colocate() {
        // nullable I64 keys: null keys from both sides must meet (null ==
        // null) even when only SOME ranks hold masks — the global layout
        // agreement. Left rows 0..6 with nulls on odd ranks' rows; right has
        // one null-keyed row and keys {2, 4}.
        use crate::column::ValidityMask;
        let out = run_spmd(3, |c| {
            let lvals: Vec<i64> = vec![0, 2 + c.rank() as i64];
            let lkc = Column::I64(lvals.clone());
            // rank 1 nulls its first key; other ranks are fully valid
            let lmask = if c.rank() == 1 {
                Some(ValidityMask::from_bools(&[false, true]))
            } else {
                None
            };
            let lpay = Column::I64(vec![10 * c.rank() as i64, 10 * c.rank() as i64 + 1]);
            // right side only on rank 0: a null key and key 2
            let (rkc, rmask, rpay) = if c.rank() == 0 {
                (
                    Column::I64(vec![0, 2]),
                    Some(ValidityMask::from_bools(&[false, true])),
                    Column::I64(vec![777, 222]),
                )
            } else {
                (Column::I64(vec![]), None, Column::I64(vec![]))
            };
            let (keys, _, rc) = distributed_join_on(
                &c,
                &[(&lkc, lmask.as_ref())],
                &[(&lpay, None)],
                &[(&rkc, rmask.as_ref())],
                &[(&rpay, None)],
                JoinType::Inner,
            )
            .unwrap();
            let mut rows = Vec::new();
            for i in 0..keys[0].len() {
                rows.push((
                    keys[0].is_valid(i),
                    keys[0].values.as_i64()[i],
                    rc[0].values.as_i64()[i],
                ));
            }
            rows
        });
        let mut all: Vec<(bool, i64, i64)> = out.into_iter().flatten().collect();
        all.sort();
        // rank 1's null key matches the right null key (777); key 2 appears
        // once on the left (rank 0's second row) matching 222
        assert_eq!(all, vec![(false, 0, 777), (true, 2, 222)]);
    }

    /// Run a single-key i64 join end to end under `strategy` and return the
    /// global output multiset as sorted row strings (`valid:value` per
    /// cell) — the strategy-agnostic comparison form. Payload cells carry
    /// the global source row id, so row identity survives any routing.
    fn run_join_multiset(
        workers: usize,
        lk_all: &[i64],
        lvalid_all: Option<&[bool]>,
        rk_all: &[i64],
        rvalid_all: Option<&[bool]>,
        how: JoinType,
        strategy: JoinStrategy,
    ) -> Vec<String> {
        let out = run_spmd(workers, |c| {
            let (ls, ll) = crate::comm::block_range(lk_all.len(), workers, c.rank());
            let (rs, rl) = crate::comm::block_range(rk_all.len(), workers, c.rank());
            // canonical form: values under null bits are the dtype default
            let lvals: Vec<i64> = (ls..ls + ll)
                .map(|i| {
                    if lvalid_all.map_or(true, |v| v[i]) {
                        lk_all[i]
                    } else {
                        0
                    }
                })
                .collect();
            let lkc = Column::I64(lvals);
            let lmask =
                lvalid_all.map(|v| ValidityMask::from_bools(&v[ls..ls + ll]));
            let lpayc = Column::I64((ls..ls + ll).map(|i| i as i64 * 10 + 1).collect());
            let rvals: Vec<i64> = (rs..rs + rl)
                .map(|i| {
                    if rvalid_all.map_or(true, |v| v[i]) {
                        rk_all[i]
                    } else {
                        0
                    }
                })
                .collect();
            let rkc = Column::I64(rvals);
            let rmask =
                rvalid_all.map(|v| ValidityMask::from_bools(&v[rs..rs + rl]));
            let rpayc =
                Column::I64((rs..rs + rl).map(|i| i as i64 * 100 + 2).collect());
            let (keys, lout, rout) = distributed_join_on_strategy(
                &c,
                &[(&lkc, lmask.as_ref())],
                &[(&lpayc, None)],
                &[(&rkc, rmask.as_ref())],
                &[(&rpayc, None)],
                how,
                strategy,
                KeyNullability::Runtime,
            )
            .unwrap();
            let mut rows = Vec::new();
            for o in 0..keys[0].len() {
                let mut srow = format!(
                    "k={}:{}",
                    keys[0].is_valid(o),
                    keys[0].values.as_i64()[o]
                );
                if let Some(col) = lout.first() {
                    srow.push_str(&format!(
                        " l={}:{}",
                        col.is_valid(o),
                        col.values.as_i64()[o]
                    ));
                }
                if let Some(col) = rout.first() {
                    srow.push_str(&format!(
                        " r={}:{}",
                        col.is_valid(o),
                        col.values.as_i64()[o]
                    ));
                }
                rows.push(srow);
            }
            rows
        });
        let mut all: Vec<String> = out.into_iter().flatten().collect();
        all.sort();
        all
    }

    #[test]
    fn skew_strategy_agrees_with_hash_all_join_types() {
        // heavy key 7 (50 % of left rows), a heavy *null* key (25 %), the
        // rest sparse; the right side has duplicate heavy build rows (the
        // both-sides-heavy case), a null build row and an unmatched key
        let n = 240usize;
        let mut lk = Vec::new();
        let mut lvalid = Vec::new();
        for i in 0..n {
            match i % 4 {
                0 | 1 => {
                    lk.push(7i64);
                    lvalid.push(true);
                }
                2 => {
                    lk.push((i % 60) as i64);
                    lvalid.push(true);
                }
                _ => {
                    lk.push(0);
                    lvalid.push(false); // null-keyed probe rows
                }
            }
        }
        let mut rk: Vec<i64> = (0..30).collect();
        let mut rvalid = vec![true; 30];
        rk.push(7);
        rvalid.push(true); // duplicate heavy build rows
        rk.push(7);
        rvalid.push(true);
        rk.push(0);
        rvalid.push(false); // null build row (matches the null probes)
        rk.push(99);
        rvalid.push(true); // unmatched build row
        for how in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::Outer,
            JoinType::Semi,
            JoinType::Anti,
        ] {
            for workers in [2usize, 3] {
                let hash = run_join_multiset(
                    workers,
                    &lk,
                    Some(&lvalid),
                    &rk,
                    Some(&rvalid),
                    how,
                    JoinStrategy::Hash,
                );
                let skew = run_join_multiset(
                    workers,
                    &lk,
                    Some(&lvalid),
                    &rk,
                    Some(&rvalid),
                    how,
                    JoinStrategy::skew_with_threshold(0.15),
                );
                assert!(!hash.is_empty(), "{how:?}: empty oracle");
                assert_eq!(hash, skew, "{how:?} workers={workers}");
            }
        }
    }

    #[test]
    fn skew_strategy_without_heavy_keys_degrades_to_hash() {
        // uniform keys: the sampling pass finds nothing heavy, so the skew
        // strategy takes the plain hash path (same output either way)
        let lk: Vec<i64> = (0..120).collect();
        let rk: Vec<i64> = (0..120).filter(|i| i % 2 == 0).collect();
        for workers in [1usize, 3] {
            let hash = run_join_multiset(
                workers,
                &lk,
                None,
                &rk,
                None,
                JoinType::Inner,
                JoinStrategy::Hash,
            );
            let skew = run_join_multiset(
                workers,
                &lk,
                None,
                &rk,
                None,
                JoinType::Inner,
                JoinStrategy::skew_with_threshold(0.1),
            );
            assert_eq!(hash.len(), 60);
            assert_eq!(hash, skew, "workers={workers}");
        }
    }

    #[test]
    fn skew_path_all_heavy_and_single_rank() {
        // threshold 1‰ marks every left key heavy → the light left
        // partition is empty and only unmatched-right flows through the
        // light shuffle; workers=1 exercises the single-rank fast-out
        // (skew degrades to the plain local hash join)
        let lk: Vec<i64> = vec![1, 1, 2, 2, 3, 3];
        let rk: Vec<i64> = vec![1, 3, 9];
        for how in [JoinType::Outer, JoinType::Right, JoinType::Anti] {
            for workers in [1usize, 2] {
                let hash = run_join_multiset(
                    workers,
                    &lk,
                    None,
                    &rk,
                    None,
                    how,
                    JoinStrategy::Hash,
                );
                let skew = run_join_multiset(
                    workers,
                    &lk,
                    None,
                    &rk,
                    None,
                    how,
                    JoinStrategy::skew_with_threshold(0.001),
                );
                assert_eq!(hash, skew, "{how:?} workers={workers}");
            }
        }
    }

    #[test]
    fn partition_heavy_splits_by_membership() {
        // single rank: detection is exact, so the partition is exact too
        run_spmd(1, |c| {
            let col = Column::I64(vec![5, 1, 5, 2, 5, 3]);
            let packed = PackedKeys::pack(&[&col]).unwrap();
            let heavy = detect_heavy_hitters(&c, &packed, 0.5);
            assert_eq!(heavy.len(), 1);
            let (h, l) = partition_heavy(&heavy, &packed);
            assert_eq!(h, vec![0, 2, 4]);
            assert_eq!(l, vec![1, 3, 5]);
        });
    }

    #[test]
    fn distributed_semi_anti_partition_left() {
        let lk_all: Vec<i64> = (0..8).collect();
        let rk_all: Vec<i64> = vec![1, 3, 5, 7, 9];
        for (how, expect) in [
            (JoinType::Semi, vec![1, 3, 5, 7]),
            (JoinType::Anti, vec![0, 2, 4, 6]),
        ] {
            let out = run_spmd(3, |c| {
                let (ls, ll) = crate::comm::block_range(lk_all.len(), 3, c.rank());
                let (rs, rl) = crate::comm::block_range(rk_all.len(), 3, c.rank());
                let lkc = Column::I64(lk_all[ls..ls + ll].to_vec());
                let rkc = Column::I64(rk_all[rs..rs + rl].to_vec());
                let (keys, _, rc) =
                    distributed_join_on(&c, &[(&lkc, None)], &[], &[(&rkc, None)], &[], how)
                        .unwrap();
                assert!(rc.is_empty());
                keys[0].values.as_i64().to_vec()
            });
            let mut got: Vec<i64> = out.into_iter().flatten().collect();
            got.sort();
            assert_eq!(got, expect, "{how:?}");
        }
    }
}
