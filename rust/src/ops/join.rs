//! Equi-join over composite keys with join types.
//!
//! Both sides are hash-partitioned by their key *tuple* so equal keys meet
//! on `owner_of_key(keys)` (the paper's hash partitioning, Fig. 5,
//! generalized from `_df_id[i] % npes` to an Fx hash over the key list).
//! The local join is a hash join producing `(left, right)` index pairs where
//! a missing side (`None`) marks the null-introduced rows of Left / Right /
//! Outer joins. Because the shuffle colocates equal keys, the unmatched-row
//! bookkeeping is purely rank-local.
//!
//! The seed's single-key sort-merge join ([`local_sort_merge_join`]) is kept
//! both as the historical reference implementation and as an oracle in the
//! property tests.

use super::keys::{KeyRow, PackedKeys};
use super::shuffle::shuffle_by_packed_nullable;
use crate::column::{Column, NullableColumn, ValidityMask};
use crate::comm::Comm;
use crate::fxhash::FxHashMap;
use crate::types::JoinType;
use anyhow::{bail, Result};

/// One column with its optional validity mask — the argument shape of the
/// nullable relational operators.
pub type MaskedCol<'a> = (&'a Column, Option<&'a ValidityMask>);

/// Does any rank contribute `local` = true? Layout decisions that feed the
/// hash-routing (flagged vs. unflagged packed keys) must be *globally*
/// consistent, or equal keys would land on different owner ranks.
pub(crate) fn global_any(comm: &Comm, local: bool) -> bool {
    comm.allgather_bytes(vec![local as u8])
        .iter()
        .any(|b| b.first().copied().unwrap_or(0) != 0)
}

/// Local sort-merge inner join over single i64 keys (the seed's kernel).
/// Returns `(left_indices, right_indices)` — one entry per output row (the
/// cross product within each equal-key group).
pub fn local_sort_merge_join(lkeys: &[i64], rkeys: &[i64]) -> (Vec<usize>, Vec<usize>) {
    let mut lidx: Vec<usize> = (0..lkeys.len()).collect();
    let mut ridx: Vec<usize> = (0..rkeys.len()).collect();
    lidx.sort_by_key(|&i| lkeys[i]); // stable = Timsort-family
    ridx.sort_by_key(|&i| rkeys[i]);

    let mut out_l = Vec::new();
    let mut out_r = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < lidx.len() && j < ridx.len() {
        let lk = lkeys[lidx[i]];
        let rk = rkeys[ridx[j]];
        if lk < rk {
            i += 1;
        } else if lk > rk {
            j += 1;
        } else {
            // find the extents of the equal-key runs
            let mut ie = i;
            while ie < lidx.len() && lkeys[lidx[ie]] == lk {
                ie += 1;
            }
            let mut je = j;
            while je < ridx.len() && rkeys[ridx[je]] == rk {
                je += 1;
            }
            for &li in &lidx[i..ie] {
                for &rj in &ridx[j..je] {
                    out_l.push(li);
                    out_r.push(rj);
                }
            }
            i = ie;
            j = je;
        }
    }
    (out_l, out_r)
}

/// Local hash join over *packed* key sets with join-type semantics — the
/// HiFrames hot path: the build table maps raw key hashes to candidate right
/// rows and tuple equality against the packed bytes resolves collisions, so
/// no per-row `Vec<KeyVal>` is ever allocated. Pair semantics and output
/// order are identical to [`local_join_pairs`] (the KeyRow reference
/// implementation, kept for the baseline engines and as the oracle in the
/// property tests).
pub fn packed_join_pairs(
    lkeys: &PackedKeys<'_>,
    rkeys: &PackedKeys<'_>,
    how: JoinType,
) -> Vec<(Option<usize>, Option<usize>)> {
    let mut index: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
    for j in 0..rkeys.len() {
        index.entry(rkeys.hash_row(j)).or_default().push(j as u32);
    }
    let mut out = Vec::new();
    let mut right_matched = vec![false; rkeys.len()];
    for i in 0..lkeys.len() {
        let mut matched = false;
        if let Some(cands) = index.get(&lkeys.hash_row(i)) {
            for &j32 in cands {
                let j = j32 as usize;
                if !lkeys.eq_rows(i, rkeys, j) {
                    continue; // hash collision between distinct tuples
                }
                matched = true;
                match how {
                    // Semi/Anti only need match existence
                    JoinType::Semi | JoinType::Anti => break,
                    _ => {
                        right_matched[j] = true;
                        out.push((Some(i), Some(j)));
                    }
                }
            }
        }
        match (matched, how) {
            (true, JoinType::Semi) => out.push((Some(i), None)),
            (false, JoinType::Left | JoinType::Outer | JoinType::Anti) => {
                out.push((Some(i), None))
            }
            _ => {}
        }
    }
    if matches!(how, JoinType::Right | JoinType::Outer) {
        for (j, m) in right_matched.iter().enumerate() {
            if !m {
                out.push((None, Some(j)));
            }
        }
    }
    out
}

/// Local hash join over key tuples with join-type semantics. Returns one
/// `(left, right)` index pair per output row; `None` marks the missing side
/// of an unmatched row (never both `None`). Left rows are visited in input
/// order; for Right/Outer the unmatched right rows follow in input order.
pub fn local_join_pairs(
    lkeys: &[KeyRow],
    rkeys: &[KeyRow],
    how: JoinType,
) -> Vec<(Option<usize>, Option<usize>)> {
    let mut index: FxHashMap<&KeyRow, Vec<usize>> = FxHashMap::default();
    for (j, k) in rkeys.iter().enumerate() {
        index.entry(k).or_default().push(j);
    }
    let mut out = Vec::new();
    let mut right_matched = vec![false; rkeys.len()];
    for (i, k) in lkeys.iter().enumerate() {
        match index.get(k) {
            Some(matches) => match how {
                JoinType::Anti => {}
                JoinType::Semi => out.push((Some(i), None)),
                _ => {
                    for &j in matches {
                        right_matched[j] = true;
                        out.push((Some(i), Some(j)));
                    }
                }
            },
            None => match how {
                JoinType::Left | JoinType::Outer => out.push((Some(i), None)),
                JoinType::Anti => out.push((Some(i), None)),
                JoinType::Inner | JoinType::Right | JoinType::Semi => {}
            },
        }
    }
    if matches!(how, JoinType::Right | JoinType::Outer) {
        for (j, m) in right_matched.iter().enumerate() {
            if !m {
                out.push((None, Some(j)));
            }
        }
    }
    out
}

/// Distributed equi-join over composite keys with validity masks.
///
/// `lkeys`/`rkeys` are the key columns (with optional masks) in `on`-pair
/// order (equal dtypes per pair, validated by plan typing); `lpay`/`rpay`
/// the non-key payload columns. Null keys are ordinary key values (null
/// matches null — the Pandas merge rule), routed/compared through the
/// validity-flagged packed layout; the flag choice is agreed globally so
/// equal keys colocate no matter which rank holds a mask. Returns:
///
/// * one output key column per pair (key dtype preserved; value and
///   validity from whichever side is present);
/// * the left payload columns (dtype preserved; unmatched rows get cleared
///   validity bits when `how.nullable_left()`);
/// * the right payload columns (empty for Semi/Anti, null-introduced when
///   `how.nullable_right()`).
///
/// Output distribution is `1D_VAR`.
pub fn distributed_join_on(
    comm: &Comm,
    lkeys: &[MaskedCol],
    lpay: &[MaskedCol],
    rkeys: &[MaskedCol],
    rpay: &[MaskedCol],
    how: JoinType,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>, Vec<NullableColumn>)> {
    if lkeys.len() != rkeys.len() || lkeys.is_empty() {
        bail!("join: key column lists must be non-empty and equal length");
    }
    // every rank (and both sides) must agree on the flagged-vs-plain key
    // layout, or the hash routing would split equal keys across ranks
    let local_flag = lkeys.iter().chain(rkeys).any(|(_, m)| m.is_some());
    let with_flags = global_any(comm, local_flag);

    fn split<'a>(
        side: &[MaskedCol<'a>],
    ) -> (Vec<&'a Column>, Vec<Option<&'a ValidityMask>>) {
        (
            side.iter().map(|(c, _)| *c).collect(),
            side.iter().map(|(_, m)| *m).collect(),
        )
    }
    let (lkc, lkm) = split(lkeys);
    let (rkc, rkm) = split(rkeys);
    let lpacked_pre = PackedKeys::pack_masked(&lkc, &lkm, with_flags)?;
    let rpacked_pre = PackedKeys::pack_masked(&rkc, &rkm, with_flags)?;

    // route both sides by the hash of their packed key set — no per-row
    // tuples, and no column clones on the way into the shuffle
    let mut lall: Vec<&Column> = lkc.clone();
    let mut lmasks: Vec<Option<&ValidityMask>> = lkm.clone();
    for (c, m) in lpay {
        lall.push(c);
        lmasks.push(*m);
    }
    let mut rall: Vec<&Column> = rkc.clone();
    let mut rmasks: Vec<Option<&ValidityMask>> = rkm.clone();
    for (c, m) in rpay {
        rall.push(c);
        rmasks.push(*m);
    }
    let (lall, lrmasks) = shuffle_by_packed_nullable(comm, &lpacked_pre, &lall, &lmasks)?;
    let (rall, rrmasks) = shuffle_by_packed_nullable(comm, &rpacked_pre, &rall, &rmasks)?;
    let (lk, lc) = lall.split_at(lkeys.len());
    let (lkm2, lcm) = lrmasks.split_at(lkeys.len());
    let (rk, rc) = rall.split_at(rkeys.len());
    let (rkm2, rcm) = rrmasks.split_at(rkeys.len());

    let lkrefs: Vec<&Column> = lk.iter().collect();
    let rkrefs: Vec<&Column> = rk.iter().collect();
    let lkmrefs: Vec<Option<&ValidityMask>> = lkm2.iter().map(|m| m.as_ref()).collect();
    let rkmrefs: Vec<Option<&ValidityMask>> = rkm2.iter().map(|m| m.as_ref()).collect();
    // post-shuffle: only the two local sides must agree on the layout
    let local_flags = lkmrefs.iter().chain(&rkmrefs).any(|m| m.is_some());
    let lpacked = PackedKeys::pack_masked(&lkrefs, &lkmrefs, local_flags)?;
    let rpacked = PackedKeys::pack_masked(&rkrefs, &rkmrefs, local_flags)?;
    let pairs = packed_join_pairs(&lpacked, &rpacked, how);

    // output key columns: value + validity from whichever side is present,
    // gathered straight from the shuffled key columns
    let keys_out: Vec<NullableColumn> = (0..lk.len())
        .map(|j| {
            take_merged(
                (&lk[j], lkmrefs[j]),
                (&rk[j], rkmrefs[j]),
                &pairs,
            )
        })
        .collect();

    let lidx: Vec<Option<usize>> = pairs.iter().map(|&(lo, _)| lo).collect();
    let left_out: Vec<NullableColumn> = if how.nullable_left() {
        lc.iter()
            .zip(lcm)
            .map(|(c, m)| c.take_opt_masked(m.as_ref(), &lidx))
            .collect()
    } else {
        let li: Vec<usize> = lidx.iter().map(|o| o.expect("left index")).collect();
        lc.iter()
            .zip(lcm)
            .map(|(c, m)| {
                NullableColumn::new(c.take(&li), m.as_ref().map(|m| m.take(&li)))
            })
            .collect()
    };

    let right_out: Vec<NullableColumn> = if !how.keeps_right_columns() {
        Vec::new()
    } else {
        let ridx: Vec<Option<usize>> = pairs.iter().map(|&(_, ro)| ro).collect();
        if how.nullable_right() {
            rc.iter()
                .zip(rcm)
                .map(|(c, m)| c.take_opt_masked(m.as_ref(), &ridx))
                .collect()
        } else {
            let ri: Vec<usize> = ridx.iter().map(|o| o.expect("right index")).collect();
            rc.iter()
                .zip(rcm)
                .map(|(c, m)| {
                    NullableColumn::new(c.take(&ri), m.as_ref().map(|m| m.take(&ri)))
                })
                .collect()
        }
    };
    Ok((keys_out, left_out, right_out))
}

/// Gather one output key column from a join's `(left, right)` index pairs:
/// each output row takes the key cell (value *and* validity bit) from
/// whichever side is present. Both columns have the key dtype (validated by
/// plan typing), so the output dtype is preserved.
fn take_merged(
    left: MaskedCol,
    right: MaskedCol,
    pairs: &[(Option<usize>, Option<usize>)],
) -> NullableColumn {
    fn pick<'v, T>(a: &'v [T], b: &'v [T], lo: Option<usize>, ro: Option<usize>) -> &'v T {
        match (lo, ro) {
            (Some(i), _) => &a[i],
            (None, Some(j)) => &b[j],
            (None, None) => unreachable!("join pair with no sides"),
        }
    }
    let (lcol, lmask) = left;
    let (rcol, rmask) = right;
    let values = match (lcol, rcol) {
        (Column::I64(a), Column::I64(b)) => Column::I64(
            pairs
                .iter()
                .map(|&(lo, ro)| *pick(a, b, lo, ro))
                .collect(),
        ),
        (Column::Bool(a), Column::Bool(b)) => Column::Bool(
            pairs
                .iter()
                .map(|&(lo, ro)| *pick(a, b, lo, ro))
                .collect(),
        ),
        (Column::Str(a), Column::Str(b)) => Column::Str(
            pairs
                .iter()
                .map(|&(lo, ro)| pick(a, b, lo, ro).clone())
                .collect(),
        ),
        (a, b) => panic!(
            "join key dtype mismatch: {:?} vs {:?}",
            a.dtype(),
            b.dtype()
        ),
    };
    let validity = if lmask.is_some() || rmask.is_some() {
        let mut m = ValidityMask::new_null(pairs.len());
        for (o, &(lo, ro)) in pairs.iter().enumerate() {
            let ok = match (lo, ro) {
                (Some(i), _) => lmask.map_or(true, |m| m.get(i)),
                (None, Some(j)) => rmask.map_or(true, |m| m.get(j)),
                (None, None) => unreachable!("join pair with no sides"),
            };
            if ok {
                m.set(o, true);
            }
        }
        Some(m)
    } else {
        None
    };
    NullableColumn::new(values, validity)
}

/// Borrowed masked views over plain columns (no masks) — adapter for
/// mask-free call sites.
pub fn plain<'a>(cols: &[&'a Column]) -> Vec<MaskedCol<'a>> {
    cols.iter().map(|&c| (c, None)).collect()
}

/// Distributed inner equi-join over single i64 keys — the seed API, now a
/// thin wrapper over [`distributed_join_on`]. Output columns: joined key,
/// then left payload columns, then right payload columns.
pub fn distributed_join(
    comm: &Comm,
    lkeys: &[i64],
    lcols: &[Column],
    rkeys: &[i64],
    rcols: &[Column],
) -> Result<(Vec<i64>, Vec<Column>, Vec<Column>)> {
    let lkc = Column::I64(lkeys.to_vec());
    let rkc = Column::I64(rkeys.to_vec());
    let lrefs: Vec<MaskedCol> = lcols.iter().map(|c| (c, None)).collect();
    let rrefs: Vec<MaskedCol> = rcols.iter().map(|c| (c, None)).collect();
    let (keys, lout, rout) = distributed_join_on(
        comm,
        &[(&lkc, None)],
        &lrefs,
        &[(&rkc, None)],
        &rrefs,
        JoinType::Inner,
    )?;
    Ok((
        keys[0].values.as_i64().to_vec(),
        lout.into_iter().map(|c| c.values).collect(),
        rout.into_iter().map(|c| c.values).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;
    use crate::ops::keys::KeyVal;

    /// Brute-force oracle.
    fn nested_loop(lk: &[i64], rk: &[i64]) -> Vec<(i64, usize, usize)> {
        let mut out = Vec::new();
        for (i, &a) in lk.iter().enumerate() {
            for (j, &b) in rk.iter().enumerate() {
                if a == b {
                    out.push((a, i, j));
                }
            }
        }
        out.sort();
        out
    }

    fn rows1(ks: &[i64]) -> Vec<KeyRow> {
        ks.iter().map(|&k| vec![KeyVal::I64(k)]).collect()
    }

    #[test]
    fn local_join_matches_oracle() {
        let lk = vec![3i64, 1, 2, 3, 3];
        let rk = vec![3i64, 3, 5, 1];
        let (li, ri) = local_sort_merge_join(&lk, &rk);
        let mut got: Vec<(i64, usize, usize)> = li
            .iter()
            .zip(&ri)
            .map(|(&i, &j)| (lk[i], i, j))
            .collect();
        got.sort();
        assert_eq!(got, nested_loop(&lk, &rk));
        // 3 appears 3×2 = 6 times, 1 appears 1×1
        assert_eq!(li.len(), 7);

        // the composite hash join agrees with the sort-merge oracle on Inner
        let pairs = local_join_pairs(&rows1(&lk), &rows1(&rk), JoinType::Inner);
        let mut got2: Vec<(i64, usize, usize)> = pairs
            .iter()
            .map(|&(l, r)| (lk[l.unwrap()], l.unwrap(), r.unwrap()))
            .collect();
        got2.sort();
        assert_eq!(got2, nested_loop(&lk, &rk));
    }

    #[test]
    fn local_join_empty_sides() {
        let (li, ri) = local_sort_merge_join(&[], &[1, 2]);
        assert!(li.is_empty() && ri.is_empty());
        let (li, _) = local_sort_merge_join(&[1], &[]);
        assert!(li.is_empty());
        assert!(local_join_pairs(&[], &rows1(&[1, 2]), JoinType::Inner).is_empty());
        assert_eq!(
            local_join_pairs(&rows1(&[1]), &[], JoinType::Left),
            vec![(Some(0), None)]
        );
    }

    #[test]
    fn local_join_no_matches() {
        let (li, _) = local_sort_merge_join(&[1, 2], &[3, 4]);
        assert!(li.is_empty());
    }

    #[test]
    fn local_join_types_semantics() {
        let lk = rows1(&[1, 2, 2, 5]);
        let rk = rows1(&[2, 3]);
        // Inner: two (2,2) matches
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Inner),
            vec![(Some(1), Some(0)), (Some(2), Some(0))]
        );
        // Left: unmatched 1 and 5 survive with None right
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Left),
            vec![
                (Some(0), None),
                (Some(1), Some(0)),
                (Some(2), Some(0)),
                (Some(3), None)
            ]
        );
        // Right: unmatched 3 survives with None left, appended after
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Right),
            vec![(Some(1), Some(0)), (Some(2), Some(0)), (None, Some(1))]
        );
        // Outer = Left ∪ unmatched right
        let outer = local_join_pairs(&lk, &rk, JoinType::Outer);
        assert_eq!(outer.len(), 5);
        assert!(outer.contains(&(None, Some(1))));
        // Semi: one row per matching left row
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Semi),
            vec![(Some(1), None), (Some(2), None)]
        );
        // Anti: the non-matching left rows
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Anti),
            vec![(Some(0), None), (Some(3), None)]
        );
    }

    #[test]
    fn packed_join_matches_keyrow_oracle_all_types() {
        use crate::ops::keys::key_rows;
        // duplicate keys on both sides, unmatched rows on both sides
        let lk1 = Column::I64(vec![1, 2, 2, 5, 7, 2]);
        let lk2 = Column::Bool(vec![true, false, false, true, false, true]);
        let rk1 = Column::I64(vec![2, 3, 2, 7]);
        let rk2 = Column::Bool(vec![false, true, false, true]);
        let lrows = key_rows(&[&lk1, &lk2]).unwrap();
        let rrows = key_rows(&[&rk1, &rk2]).unwrap();
        let lp = PackedKeys::pack(&[&lk1, &lk2]).unwrap();
        let rp = PackedKeys::pack(&[&rk1, &rk2]).unwrap();
        for how in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::Outer,
            JoinType::Semi,
            JoinType::Anti,
        ] {
            assert_eq!(
                packed_join_pairs(&lp, &rp, how),
                local_join_pairs(&lrows, &rrows, how),
                "{how:?}"
            );
        }
        // single-i64 (zero-copy layout) as well
        let a = Column::I64(vec![3, 1, 3, 9]);
        let b = Column::I64(vec![3, 4]);
        let pa = PackedKeys::pack(&[&a]).unwrap();
        let pb = PackedKeys::pack(&[&b]).unwrap();
        for how in [JoinType::Inner, JoinType::Outer, JoinType::Anti] {
            assert_eq!(
                packed_join_pairs(&pa, &pb, how),
                local_join_pairs(
                    &rows1(a.as_i64()),
                    &rows1(b.as_i64()),
                    how
                ),
                "{how:?}"
            );
        }
    }

    #[test]
    fn local_join_composite_keys() {
        let lk = vec![
            vec![KeyVal::I64(1), KeyVal::Str("a".into())],
            vec![KeyVal::I64(1), KeyVal::Str("b".into())],
        ];
        let rk = vec![vec![KeyVal::I64(1), KeyVal::Str("a".into())]];
        // only the full tuple (1,"a") matches — single-column equality is
        // not enough
        assert_eq!(
            local_join_pairs(&lk, &rk, JoinType::Inner),
            vec![(Some(0), Some(0))]
        );
    }

    #[test]
    fn distributed_join_matches_serial() {
        // global data split over 3 ranks
        let lk_all: Vec<i64> = vec![1, 2, 3, 4, 5, 6, 2, 3];
        let rk_all: Vec<i64> = vec![2, 2, 3, 9];
        let out = run_spmd(3, |c| {
            let (ls, ll) = crate::comm::block_range(lk_all.len(), 3, c.rank());
            let (rs, rl) = crate::comm::block_range(rk_all.len(), 3, c.rank());
            let lk = &lk_all[ls..ls + ll];
            let rk = &rk_all[rs..rs + rl];
            let lvals = Column::I64(lk.iter().map(|&k| k * 10).collect());
            let rvals = Column::I64(rk.iter().map(|&k| k * 100).collect());
            let (keys, lc, rc) =
                distributed_join(&c, lk, &[lvals], rk, &[rvals]).unwrap();
            (keys, lc[0].as_i64().to_vec(), rc[0].as_i64().to_vec())
        });
        let mut rows: Vec<(i64, i64, i64)> = out
            .iter()
            .flat_map(|(k, l, r)| {
                k.iter()
                    .zip(l.iter())
                    .zip(r.iter())
                    .map(|((&k, &l), &r)| (k, l, r))
            })
            .collect();
        rows.sort();
        // serial expectation: key 2 matches 2×2=4 rows, key 3 matches 2×1=2
        let expect: Vec<(i64, i64, i64)> = vec![
            (2, 20, 200),
            (2, 20, 200),
            (2, 20, 200),
            (2, 20, 200),
            (3, 30, 300),
            (3, 30, 300),
        ];
        assert_eq!(rows, expect);
        // payload invariants: l = 10k, r = 100k
        for (k, l, r) in rows {
            assert_eq!(l, k * 10);
            assert_eq!(r, k * 100);
        }
    }

    #[test]
    fn distributed_left_join_masks_unmatched() {
        // left keys 0..6 over 2 ranks; right covers only even keys
        let lk_all: Vec<i64> = (0..6).collect();
        let rk_all: Vec<i64> = vec![0, 2, 4];
        let out = run_spmd(2, |c| {
            let (ls, ll) = crate::comm::block_range(lk_all.len(), 2, c.rank());
            let (rs, rl) = crate::comm::block_range(rk_all.len(), 2, c.rank());
            let lkc = Column::I64(lk_all[ls..ls + ll].to_vec());
            let lval = Column::I64(lk_all[ls..ls + ll].iter().map(|k| k + 100).collect());
            let rkc = Column::I64(rk_all[rs..rs + rl].to_vec());
            let rval = Column::I64(rk_all[rs..rs + rl].iter().map(|k| k + 200).collect());
            let (keys, lc, rc) = distributed_join_on(
                &c,
                &[(&lkc, None)],
                &[(&lval, None)],
                &[(&rkc, None)],
                &[(&rval, None)],
                JoinType::Left,
            )
            .unwrap();
            // the right payload keeps its Int64 dtype — nulls live in the mask
            assert_eq!(rc[0].dtype(), crate::types::DType::I64);
            assert!(lc[0].validity.is_none(), "left side of a left join never null");
            (
                keys[0].values.as_i64().to_vec(),
                lc[0].values.as_i64().to_vec(),
                rc[0].values.as_i64().to_vec(),
                (0..rc[0].len()).map(|i| rc[0].is_valid(i)).collect::<Vec<_>>(),
            )
        });
        let mut rows: Vec<(i64, i64, i64, bool)> = out
            .iter()
            .flat_map(|(k, l, r, v)| {
                k.iter()
                    .zip(l.iter())
                    .zip(r.iter().zip(v.iter()))
                    .map(|((&k, &l), (&r, &v))| (k, l, r, v))
            })
            .collect();
        rows.sort();
        assert_eq!(rows.len(), 6); // every left row survives
        for (k, l, r, valid) in &rows {
            assert_eq!(*l, k + 100);
            if k % 2 == 0 {
                assert!(valid, "matched row {k} must be valid");
                assert_eq!(*r, k + 200);
            } else {
                assert!(!valid, "unmatched row {k} must be null");
                assert_eq!(*r, 0, "null lanes hold the dtype default");
            }
        }
    }

    #[test]
    fn distributed_join_on_nullable_keys_colocate() {
        // nullable I64 keys: null keys from both sides must meet (null ==
        // null) even when only SOME ranks hold masks — the global layout
        // agreement. Left rows 0..6 with nulls on odd ranks' rows; right has
        // one null-keyed row and keys {2, 4}.
        use crate::column::ValidityMask;
        let out = run_spmd(3, |c| {
            let lvals: Vec<i64> = vec![0, 2 + c.rank() as i64];
            let lkc = Column::I64(lvals.clone());
            // rank 1 nulls its first key; other ranks are fully valid
            let lmask = if c.rank() == 1 {
                Some(ValidityMask::from_bools(&[false, true]))
            } else {
                None
            };
            let lpay = Column::I64(vec![10 * c.rank() as i64, 10 * c.rank() as i64 + 1]);
            // right side only on rank 0: a null key and key 2
            let (rkc, rmask, rpay) = if c.rank() == 0 {
                (
                    Column::I64(vec![0, 2]),
                    Some(ValidityMask::from_bools(&[false, true])),
                    Column::I64(vec![777, 222]),
                )
            } else {
                (Column::I64(vec![]), None, Column::I64(vec![]))
            };
            let (keys, _, rc) = distributed_join_on(
                &c,
                &[(&lkc, lmask.as_ref())],
                &[(&lpay, None)],
                &[(&rkc, rmask.as_ref())],
                &[(&rpay, None)],
                JoinType::Inner,
            )
            .unwrap();
            let mut rows = Vec::new();
            for i in 0..keys[0].len() {
                rows.push((
                    keys[0].is_valid(i),
                    keys[0].values.as_i64()[i],
                    rc[0].values.as_i64()[i],
                ));
            }
            rows
        });
        let mut all: Vec<(bool, i64, i64)> = out.into_iter().flatten().collect();
        all.sort();
        // rank 1's null key matches the right null key (777); key 2 appears
        // once on the left (rank 0's second row) matching 222
        assert_eq!(all, vec![(false, 0, 777), (true, 2, 222)]);
    }

    #[test]
    fn distributed_semi_anti_partition_left() {
        let lk_all: Vec<i64> = (0..8).collect();
        let rk_all: Vec<i64> = vec![1, 3, 5, 7, 9];
        for (how, expect) in [
            (JoinType::Semi, vec![1, 3, 5, 7]),
            (JoinType::Anti, vec![0, 2, 4, 6]),
        ] {
            let out = run_spmd(3, |c| {
                let (ls, ll) = crate::comm::block_range(lk_all.len(), 3, c.rank());
                let (rs, rl) = crate::comm::block_range(rk_all.len(), 3, c.rank());
                let lkc = Column::I64(lk_all[ls..ls + ll].to_vec());
                let rkc = Column::I64(rk_all[rs..rs + rl].to_vec());
                let (keys, _, rc) =
                    distributed_join_on(&c, &[(&lkc, None)], &[], &[(&rkc, None)], &[], how)
                        .unwrap();
                assert!(rc.is_empty());
                keys[0].values.as_i64().to_vec()
            });
            let mut got: Vec<i64> = out.into_iter().flatten().collect();
            got.sort();
            assert_eq!(got, expect, "{how:?}");
        }
    }
}
