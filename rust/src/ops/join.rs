//! Equi-join: hash-partition both sides by key, then local sort-merge
//! (paper §4.5: "we use sort-merge for join, with Timsort as the sorting
//! algorithm" — Rust's stable `sort_by_key` is a Timsort-family merge sort).

use super::shuffle::shuffle_by_key;
use crate::column::Column;
use crate::comm::Comm;
use anyhow::Result;

/// Local sort-merge join. Returns `(left_indices, right_indices)` — one
/// entry per output row (the cross product within each equal-key group).
pub fn local_sort_merge_join(lkeys: &[i64], rkeys: &[i64]) -> (Vec<usize>, Vec<usize>) {
    let mut lidx: Vec<usize> = (0..lkeys.len()).collect();
    let mut ridx: Vec<usize> = (0..rkeys.len()).collect();
    lidx.sort_by_key(|&i| lkeys[i]); // stable = Timsort-family
    ridx.sort_by_key(|&i| rkeys[i]);

    let mut out_l = Vec::new();
    let mut out_r = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < lidx.len() && j < ridx.len() {
        let lk = lkeys[lidx[i]];
        let rk = rkeys[ridx[j]];
        if lk < rk {
            i += 1;
        } else if lk > rk {
            j += 1;
        } else {
            // find the extents of the equal-key runs
            let mut ie = i;
            while ie < lidx.len() && lkeys[lidx[ie]] == lk {
                ie += 1;
            }
            let mut je = j;
            while je < ridx.len() && rkeys[ridx[je]] == rk {
                je += 1;
            }
            for &li in &lidx[i..ie] {
                for &rj in &ridx[j..je] {
                    out_l.push(li);
                    out_r.push(rj);
                }
            }
            i = ie;
            j = je;
        }
    }
    (out_l, out_r)
}

/// Distributed inner equi-join. Both sides are shuffled so equal keys meet
/// on `owner_of(key)`; the local join follows. Output columns: joined key,
/// then left payload columns, then right payload columns. Output
/// distribution is `1D_VAR`.
pub fn distributed_join(
    comm: &Comm,
    lkeys: &[i64],
    lcols: &[Column],
    rkeys: &[i64],
    rcols: &[Column],
) -> Result<(Vec<i64>, Vec<Column>, Vec<Column>)> {
    let (lk, lc) = shuffle_by_key(comm, lkeys, lcols)?;
    let (rk, rc) = shuffle_by_key(comm, rkeys, rcols)?;
    let (li, ri) = local_sort_merge_join(&lk, &rk);
    let keys: Vec<i64> = li.iter().map(|&i| lk[i]).collect();
    let left_out: Vec<Column> = lc.iter().map(|c| c.take(&li)).collect();
    let right_out: Vec<Column> = rc.iter().map(|c| c.take(&ri)).collect();
    Ok((keys, left_out, right_out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;

    /// Brute-force oracle.
    fn nested_loop(lk: &[i64], rk: &[i64]) -> Vec<(i64, usize, usize)> {
        let mut out = Vec::new();
        for (i, &a) in lk.iter().enumerate() {
            for (j, &b) in rk.iter().enumerate() {
                if a == b {
                    out.push((a, i, j));
                }
            }
        }
        out.sort();
        out
    }

    #[test]
    fn local_join_matches_oracle() {
        let lk = vec![3i64, 1, 2, 3, 3];
        let rk = vec![3i64, 3, 5, 1];
        let (li, ri) = local_sort_merge_join(&lk, &rk);
        let mut got: Vec<(i64, usize, usize)> = li
            .iter()
            .zip(&ri)
            .map(|(&i, &j)| (lk[i], i, j))
            .collect();
        got.sort();
        assert_eq!(got, nested_loop(&lk, &rk));
        // 3 appears 3×2 = 6 times, 1 appears 1×1
        assert_eq!(li.len(), 7);
    }

    #[test]
    fn local_join_empty_sides() {
        let (li, ri) = local_sort_merge_join(&[], &[1, 2]);
        assert!(li.is_empty() && ri.is_empty());
        let (li, _) = local_sort_merge_join(&[1], &[]);
        assert!(li.is_empty());
    }

    #[test]
    fn local_join_no_matches() {
        let (li, _) = local_sort_merge_join(&[1, 2], &[3, 4]);
        assert!(li.is_empty());
    }

    #[test]
    fn distributed_join_matches_serial() {
        // global data split over 3 ranks
        let lk_all: Vec<i64> = vec![1, 2, 3, 4, 5, 6, 2, 3];
        let rk_all: Vec<i64> = vec![2, 2, 3, 9];
        let out = run_spmd(3, |c| {
            let (ls, ll) = crate::comm::block_range(lk_all.len(), 3, c.rank());
            let (rs, rl) = crate::comm::block_range(rk_all.len(), 3, c.rank());
            let lk = &lk_all[ls..ls + ll];
            let rk = &rk_all[rs..rs + rl];
            let lvals = Column::I64(lk.iter().map(|&k| k * 10).collect());
            let rvals = Column::I64(rk.iter().map(|&k| k * 100).collect());
            let (keys, lc, rc) =
                distributed_join(&c, lk, &[lvals], rk, &[rvals]).unwrap();
            (keys, lc[0].as_i64().to_vec(), rc[0].as_i64().to_vec())
        });
        let mut rows: Vec<(i64, i64, i64)> = out
            .iter()
            .flat_map(|(k, l, r)| {
                k.iter()
                    .zip(l.iter())
                    .zip(r.iter())
                    .map(|((&k, &l), &r)| (k, l, r))
            })
            .collect();
        rows.sort();
        // serial expectation: key 2 matches 2×2=4 rows, key 3 matches 2×1=2
        let expect: Vec<(i64, i64, i64)> = vec![
            (2, 20, 200),
            (2, 20, 200),
            (2, 20, 200),
            (2, 20, 200),
            (3, 30, 300),
            (3, 30, 300),
        ];
        assert_eq!(rows, expect);
        // payload invariants: l = 10k, r = 100k
        for (k, l, r) in rows {
            assert_eq!(l, k * 10);
            assert_eq!(r, k * 100);
        }
    }
}
