//! Distributed heavy-hitter detection — the sampling half of the
//! skew-aware join (paper §5.1's load-imbalance mitigation).
//!
//! Hash-partitioned joins route every row of a key `k` to
//! `hash(k) % nranks`, so a key holding a constant fraction of the probe
//! side concentrates that fraction of the join on a single rank. The
//! mitigation needs the set of such keys *before* the shuffle, and every
//! rank (and both join sides) must agree on it exactly — membership decides
//! whether a row is shuffled or broadcast, and a disagreement would lose or
//! duplicate rows.
//!
//! [`detect_heavy_hitters`] therefore runs a deterministic protocol:
//!
//! 1. every rank takes a strided sample of up to [`SAMPLE_PER_RANK`] of its
//!    local probe-side key tuples (encoded via
//!    [`PackedKeys::append_row_bytes`]) and tags it with its local row
//!    count;
//! 2. one `allgather` ships all samples everywhere;
//! 3. every rank merges the samples in rank order, weighting each sampled
//!    tuple by `local_rows / local_sample` so unequal chunk sizes do not
//!    bias the estimate, and keeps the tuples whose estimated global
//!    frequency share reaches the threshold.
//!
//! The merge is a pure function of the gathered bytes, so all ranks compute
//! the same [`HeavySet`]. Null keys need no special casing: a null cell is
//! part of the packed encoding (validity-flag byte ordered before the value
//! bytes), so a heavy *null* key is detected and broadcast like any other
//! heavy tuple, preserving the null == null join rule.

use crate::comm::Comm;
use crate::fxhash::FxHashMap;
use crate::ops::keys::PackedKeys;

/// Maximum sampled rows per rank. 256 samples bound the share estimate's
/// standard error near `sqrt(0.1·0.9/256) ≈ 1.9 %` at the 10 % default
/// threshold — ample for a binary heavy/light call — while keeping the
/// allgather payload a few KiB per rank.
pub const SAMPLE_PER_RANK: usize = 256;

/// The globally agreed set of heavy-hitter key tuples, keyed by the packed
/// row hash with encoded-byte candidate lists resolving collisions (the
/// same two-level scheme as the packed hash join's build table).
#[derive(Debug, Default)]
pub struct HeavySet {
    rows: FxHashMap<u64, Vec<Vec<u8>>>,
    len: usize,
}

impl HeavySet {
    /// The empty set — every key takes the hash path.
    pub fn empty() -> HeavySet {
        HeavySet::default()
    }

    /// Number of heavy key tuples.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is no key heavy? (The join then falls back to the pure hash path.)
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Is row `i` of `keys` a heavy tuple? `keys` must share the layout the
    /// set was detected on (same key dtypes, same validity-flag choice) —
    /// guaranteed for the two sides of a join, which pack under one
    /// globally agreed flag.
    #[inline]
    pub fn contains(&self, keys: &PackedKeys, i: usize) -> bool {
        match self.rows.get(&keys.hash_row(i)) {
            Some(cands) => cands.iter().any(|enc| keys.row_matches(i, enc)),
            None => false,
        }
    }

    fn insert(&mut self, hash: u64, encoded: Vec<u8>) {
        self.rows.entry(hash).or_default().push(encoded);
        self.len += 1;
    }
}

/// Sample wire format: `u64 local_rows · u64 sample_count · sample_count ×
/// (u32 len + encoded tuple)`.
fn encode_sample(keys: &PackedKeys, buf: &mut Vec<u8>) {
    let n = keys.len();
    let s = n.min(SAMPLE_PER_RANK);
    buf.extend_from_slice(&(n as u64).to_le_bytes());
    buf.extend_from_slice(&(s as u64).to_le_bytes());
    for k in 0..s {
        // strided positions cover the whole chunk deterministically; the
        // data has no meaningful row-order correlation post block-split, so
        // this matches a uniform sample without needing a shared RNG
        let i = k * n / s;
        let at = buf.len();
        buf.extend_from_slice(&0u32.to_le_bytes());
        keys.append_row_bytes(i, buf);
        let len = (buf.len() - at - 4) as u32;
        buf[at..at + 4].copy_from_slice(&len.to_le_bytes());
    }
}

/// Detect the heavy-hitter key tuples of a distributed key set (see the
/// module docs for the protocol). `threshold` is the minimum estimated
/// global frequency share (e.g. `0.1`); the result is identical on every
/// rank. One collective (`allgather`).
pub fn detect_heavy_hitters(
    comm: &Comm,
    keys: &PackedKeys,
    threshold: f64,
) -> HeavySet {
    let mut local = Vec::new();
    encode_sample(keys, &mut local);
    let gathered = comm.allgather_bytes(local);

    // merge in rank order: weight = local_rows / local_sample per tuple
    let mut weights: FxHashMap<u64, Vec<(Vec<u8>, f64)>> = FxHashMap::default();
    let mut total_rows = 0f64;
    for chunk in &gathered {
        let mut pos = 0usize;
        let read_u64 = |pos: &mut usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&chunk[*pos..*pos + 8]);
            *pos += 8;
            u64::from_le_bytes(b)
        };
        let n = read_u64(&mut pos) as f64;
        let s = read_u64(&mut pos) as usize;
        total_rows += n;
        let w = if s > 0 { n / s as f64 } else { 0.0 };
        for _ in 0..s {
            let mut lb = [0u8; 4];
            lb.copy_from_slice(&chunk[pos..pos + 4]);
            pos += 4;
            let len = u32::from_le_bytes(lb) as usize;
            let enc = &chunk[pos..pos + len];
            pos += len;
            let hash = keys.hash_encoded_row(enc);
            let cands = weights.entry(hash).or_default();
            let mut found = false;
            for (e, acc) in cands.iter_mut() {
                if e.as_slice() == enc {
                    *acc += w;
                    found = true;
                    break;
                }
            }
            if !found {
                cands.push((enc.to_vec(), w));
            }
        }
    }

    let mut heavy = HeavySet::empty();
    if total_rows <= 0.0 {
        return heavy;
    }
    for (hash, cands) in weights {
        for (enc, w) in cands {
            if w / total_rows >= threshold {
                heavy.insert(hash, enc);
            }
        }
    }
    heavy
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::{Column, ValidityMask};
    use crate::comm::run_spmd;

    #[test]
    fn detects_the_hot_key_on_every_rank() {
        // 3 ranks; key 7 holds half of every rank's rows, the rest are
        // (nearly) unique per rank
        let out = run_spmd(3, |c| {
            let r = c.rank() as i64;
            let mut keys: Vec<i64> = Vec::new();
            for i in 0..400i64 {
                keys.push(if i % 2 == 0 { 7 } else { 1000 * (r + 1) + i });
            }
            let col = Column::I64(keys);
            let packed = PackedKeys::pack(&[&col]).unwrap();
            let heavy = detect_heavy_hitters(&c, &packed, 0.2);
            // membership over a fresh packing of the probe values
            let probe = Column::I64(vec![7, 8, 1001]);
            let pp = PackedKeys::pack(&[&probe]).unwrap();
            (
                heavy.len(),
                (0..3).map(|i| heavy.contains(&pp, i)).collect::<Vec<_>>(),
            )
        });
        for (len, hits) in out {
            assert_eq!(len, 1, "only key 7 is heavy");
            assert_eq!(hits, vec![true, false, false]);
        }
    }

    #[test]
    fn uniform_keys_yield_empty_set() {
        let out = run_spmd(2, |c| {
            let keys: Vec<i64> =
                (0..500).map(|i| i * 2 + c.rank() as i64).collect();
            let col = Column::I64(keys);
            let packed = PackedKeys::pack(&[&col]).unwrap();
            detect_heavy_hitters(&c, &packed, 0.1).len()
        });
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    fn empty_and_lopsided_ranks_agree() {
        // rank 1 holds no rows at all; rank 0's hot key must still be
        // globally heavy and the sets identical
        let out = run_spmd(2, |c| {
            let keys: Vec<i64> = if c.rank() == 0 {
                vec![3; 300]
            } else {
                Vec::new()
            };
            let col = Column::I64(keys);
            let packed = PackedKeys::pack(&[&col]).unwrap();
            let heavy = detect_heavy_hitters(&c, &packed, 0.5);
            let probe = Column::I64(vec![3]);
            let pp = PackedKeys::pack(&[&probe]).unwrap();
            (heavy.len(), heavy.contains(&pp, 0))
        });
        assert_eq!(out, vec![(1, true), (1, true)]);
    }

    #[test]
    fn nullable_heavy_key_is_detected() {
        // half the rows carry a null key: with the flagged layout the null
        // tuple is itself a heavy hitter, and a valid 0 is NOT conflated
        // with it (the flag byte separates them)
        let out = run_spmd(2, |c| {
            let n = 300usize;
            let col = Column::I64(vec![0i64; n]);
            let mask = ValidityMask::from_bools(
                &(0..n).map(|i| i % 2 == 0).collect::<Vec<_>>(),
            );
            let packed =
                PackedKeys::pack_masked(&[&col], &[Some(&mask)], true).unwrap();
            let heavy = detect_heavy_hitters(&c, &packed, 0.3);
            let _ = c.rank();
            // probe: row 0 null, row 1 valid 0 — both heavy here (each holds
            // half the rows), and distinct entries
            (heavy.len(), heavy.contains(&packed, 1), {
                let all_valid =
                    PackedKeys::pack_masked(&[&col], &[None], true).unwrap();
                heavy.contains(&all_valid, 0)
            })
        });
        for (len, null_row_heavy, valid_row_heavy) in out {
            assert_eq!(len, 2, "null tuple and valid 0 are separate entries");
            assert!(null_row_heavy);
            assert!(valid_row_heavy);
        }
    }
}
