//! Generalized window kernels — the runtime of [`crate::ir::Plan::Window`]
//! (paper §4.5, generalizing the `cumsum`/stencil codegen): rolling frames
//! lower to a near-neighbor *halo exchange* (asymmetric: `preceding` rows
//! from the left neighbor, `following` rows from the right), cumulative
//! frames lower to `MPI_Exscan` scans, and shift frames are a one-sided
//! halo whose out-of-range edge rows become NULL via the validity mask.
//! This is precisely the communication class map-reduce engines cannot
//! express (Fig. 8b) — the sparklike baseline gathers everything onto one
//! executor instead.
//!
//! Null model: window aggregates skip null input lanes (like group-by
//! aggregates); an all-null frame yields 0 for `sum`/`count` and NULL for
//! `mean`/`min`/`max`/`weighted`. The weighted function renormalizes by the
//! weight mass of the lanes actually used, which makes edge truncation and
//! null skipping the *same* rule — and keeps the non-null path bit-for-bit
//! identical to the historical stencil ([`crate::ops::stencil`], whose
//! serial/halo internals it reuses).
//!
//! Partitioned windows never reach this module's communication paths: the
//! exec layer colocates each partition with a `PackedKeys` hash shuffle and
//! calls [`window_over_groups`] on the locally sorted runs, so no halo ever
//! crosses a partition boundary.

use super::keys::{KeyRow, SortKeys};
use super::scan::{cumsum_f64, cumsum_i64};
use super::stencil::stencil_1d;
use crate::column::{
    decode_nullable_column, encode_nullable_column, extend_opt_mask, normalize_mask, Column,
    NullableColumn, ValidityMask,
};
use crate::comm::{Comm, ReduceOp};
use crate::types::{SortOrder, WindowFrame, WindowFunc};
use anyhow::{bail, Context, Result};

#[inline]
fn is_valid(mask: Option<&ValidityMask>, i: usize) -> bool {
    mask.map_or(true, |m| m.get(i))
}

/// 1-based row numbers `start+1 ..= start+n` as an Int64 column.
pub fn row_numbers(n: usize, start: i64) -> Column {
    Column::I64((0..n as i64).map(|i| start + i + 1).collect())
}

/// Competition ranks (1, 1, 3, …) from order-key change flags: `breaks[i]`
/// is true where row `i`'s order-key tuple differs from row `i-1`'s (the
/// first row of a run always counts as a break).
pub fn rank_from_breaks(breaks: &[bool]) -> Column {
    let mut out: Vec<i64> = Vec::with_capacity(breaks.len());
    for (i, &b) in breaks.iter().enumerate() {
        if i == 0 || b {
            out.push(i as i64 + 1);
        } else {
            let prev = out[i - 1];
            out.push(prev);
        }
    }
    Column::I64(out)
}

/// `out[i] = col[i - offset]` (positive = lag, negative = lead); rows whose
/// source falls outside the array — or is itself null — come back NULL.
/// Works for every dtype (shift is pure index routing).
pub fn shift_window(col: &Column, mask: Option<&ValidityMask>, offset: i64) -> NullableColumn {
    let n = col.len();
    let idx: Vec<Option<usize>> = (0..n)
        .map(|i| {
            let j = i as i64 - offset;
            if j >= 0 && (j as usize) < n {
                Some(j as usize)
            } else {
                None
            }
        })
        .collect();
    col.take_opt_masked(mask, &idx)
}

/// Rolling aggregate over `[i-preceding, i+following]` with truncated edges
/// and null-skipping (see the module docs for the all-null rules).
pub fn rolling_window(
    col: &Column,
    mask: Option<&ValidityMask>,
    preceding: usize,
    following: usize,
    func: &WindowFunc,
) -> Result<NullableColumn> {
    let n = col.len();
    let lo = |i: usize| i.saturating_sub(preceding);
    let hi = |i: usize| (i + following + 1).min(n);
    match func {
        WindowFunc::Count => {
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                out.push((lo(i)..hi(i)).filter(|&j| is_valid(mask, j)).count() as i64);
            }
            Ok(NullableColumn::from_column(Column::I64(out)))
        }
        WindowFunc::Sum => match col {
            Column::I64(xs) => {
                let mut out = Vec::with_capacity(n);
                for i in 0..n {
                    let mut acc = 0i64;
                    for j in lo(i)..hi(i) {
                        if is_valid(mask, j) {
                            acc += xs[j];
                        }
                    }
                    out.push(acc);
                }
                Ok(NullableColumn::from_column(Column::I64(out)))
            }
            Column::F64(xs) => {
                let mut out = Vec::with_capacity(n);
                for i in 0..n {
                    let mut acc = 0.0;
                    for j in lo(i)..hi(i) {
                        if is_valid(mask, j) {
                            acc += xs[j];
                        }
                    }
                    out.push(acc);
                }
                Ok(NullableColumn::from_column(Column::F64(out)))
            }
            other => bail!("window sum over {} column", other.dtype()),
        },
        WindowFunc::Mean => {
            let xs = col.to_f64_vec();
            let mut out = Vec::with_capacity(n);
            let mut m = ValidityMask::new_valid(n);
            for i in 0..n {
                let mut acc = 0.0;
                let mut cnt = 0usize;
                for j in lo(i)..hi(i) {
                    if is_valid(mask, j) {
                        acc += xs[j];
                        cnt += 1;
                    }
                }
                if cnt == 0 {
                    out.push(0.0);
                    m.set(i, false);
                } else {
                    out.push(acc / cnt as f64);
                }
            }
            Ok(NullableColumn::new(
                Column::F64(out),
                normalize_mask(Some(m)),
            ))
        }
        WindowFunc::Min | WindowFunc::Max => {
            let want_min = matches!(func, WindowFunc::Min);
            match col {
                Column::I64(xs) => {
                    let mut out = Vec::with_capacity(n);
                    let mut m = ValidityMask::new_valid(n);
                    for i in 0..n {
                        let mut best: Option<i64> = None;
                        for j in lo(i)..hi(i) {
                            if is_valid(mask, j) {
                                best = Some(match best {
                                    None => xs[j],
                                    Some(b) if want_min => b.min(xs[j]),
                                    Some(b) => b.max(xs[j]),
                                });
                            }
                        }
                        match best {
                            Some(b) => out.push(b),
                            None => {
                                out.push(0);
                                m.set(i, false);
                            }
                        }
                    }
                    Ok(NullableColumn::new(
                        Column::I64(out),
                        normalize_mask(Some(m)),
                    ))
                }
                Column::F64(xs) => {
                    let mut out = Vec::with_capacity(n);
                    let mut m = ValidityMask::new_valid(n);
                    for i in 0..n {
                        let mut best: Option<f64> = None;
                        for j in lo(i)..hi(i) {
                            if is_valid(mask, j) {
                                best = Some(match best {
                                    None => xs[j],
                                    Some(b) if want_min => b.min(xs[j]),
                                    Some(b) => b.max(xs[j]),
                                });
                            }
                        }
                        match best {
                            Some(b) => out.push(b),
                            None => {
                                out.push(0.0);
                                m.set(i, false);
                            }
                        }
                    }
                    Ok(NullableColumn::new(
                        Column::F64(out),
                        normalize_mask(Some(m)),
                    ))
                }
                other => bail!("window min/max over {} column", other.dtype()),
            }
        }
        WindowFunc::Weighted(w) => {
            // truncated + renormalized — identical arithmetic (same term
            // order) to `stencil_serial` on a fully valid column
            let xs = col.to_f64_vec();
            let wtotal: f64 = w.iter().sum();
            let mut out = Vec::with_capacity(n);
            let mut m = ValidityMask::new_valid(n);
            for i in 0..n {
                let mut acc = 0.0;
                let mut used = 0.0;
                let mut seen = false;
                for (j, &wj) in w.iter().enumerate() {
                    let idx = i as isize + j as isize - preceding as isize;
                    if idx >= 0 && (idx as usize) < n && is_valid(mask, idx as usize) {
                        acc += wj * xs[idx as usize];
                        used += wj;
                        seen = true;
                    }
                }
                if !seen {
                    out.push(0.0);
                    m.set(i, false);
                } else {
                    out.push(if used != 0.0 { acc * wtotal / used } else { 0.0 });
                }
            }
            Ok(NullableColumn::new(
                Column::F64(out),
                normalize_mask(Some(m)),
            ))
        }
        other => bail!("rolling frame cannot carry {other}"),
    }
}

/// Serial cumulative (`ROWS UNBOUNDED PRECEDING .. CURRENT ROW`) scan with
/// null-skipping: every row sees the reduction over the *valid* rows up to
/// and including itself.
pub fn cumulative_window(
    col: &Column,
    mask: Option<&ValidityMask>,
    func: &WindowFunc,
) -> Result<NullableColumn> {
    let n = col.len();
    match func {
        WindowFunc::Sum => match col {
            Column::I64(xs) => {
                let mut run = 0i64;
                let mut out = Vec::with_capacity(n);
                for (i, &x) in xs.iter().enumerate() {
                    if is_valid(mask, i) {
                        run += x;
                    }
                    out.push(run);
                }
                Ok(NullableColumn::from_column(Column::I64(out)))
            }
            Column::F64(xs) => {
                let mut run = 0.0;
                let mut out = Vec::with_capacity(n);
                for (i, &x) in xs.iter().enumerate() {
                    if is_valid(mask, i) {
                        run += x;
                    }
                    out.push(run);
                }
                Ok(NullableColumn::from_column(Column::F64(out)))
            }
            other => bail!("window sum over {} column", other.dtype()),
        },
        WindowFunc::Count => {
            let mut run = 0i64;
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                if is_valid(mask, i) {
                    run += 1;
                }
                out.push(run);
            }
            Ok(NullableColumn::from_column(Column::I64(out)))
        }
        WindowFunc::Mean => {
            let xs = col.to_f64_vec();
            let mut sum = 0.0;
            let mut cnt = 0i64;
            let mut out = Vec::with_capacity(n);
            let mut m = ValidityMask::new_valid(n);
            for (i, &x) in xs.iter().enumerate() {
                if is_valid(mask, i) {
                    sum += x;
                    cnt += 1;
                }
                if cnt == 0 {
                    out.push(0.0);
                    m.set(i, false);
                } else {
                    out.push(sum / cnt as f64);
                }
            }
            Ok(NullableColumn::new(
                Column::F64(out),
                normalize_mask(Some(m)),
            ))
        }
        WindowFunc::Min | WindowFunc::Max => {
            let want_min = matches!(func, WindowFunc::Min);
            match col {
                Column::I64(xs) => {
                    let mut best: Option<i64> = None;
                    let mut out = Vec::with_capacity(n);
                    let mut m = ValidityMask::new_valid(n);
                    for (i, &x) in xs.iter().enumerate() {
                        if is_valid(mask, i) {
                            best = Some(match best {
                                None => x,
                                Some(b) if want_min => b.min(x),
                                Some(b) => b.max(x),
                            });
                        }
                        match best {
                            Some(b) => out.push(b),
                            None => {
                                out.push(0);
                                m.set(i, false);
                            }
                        }
                    }
                    Ok(NullableColumn::new(
                        Column::I64(out),
                        normalize_mask(Some(m)),
                    ))
                }
                Column::F64(xs) => {
                    let mut best: Option<f64> = None;
                    let mut out = Vec::with_capacity(n);
                    let mut m = ValidityMask::new_valid(n);
                    for (i, &x) in xs.iter().enumerate() {
                        if is_valid(mask, i) {
                            best = Some(match best {
                                None => x,
                                Some(b) if want_min => b.min(x),
                                Some(b) => b.max(x),
                            });
                        }
                        match best {
                            Some(b) => out.push(b),
                            None => {
                                out.push(0.0);
                                m.set(i, false);
                            }
                        }
                    }
                    Ok(NullableColumn::new(
                        Column::F64(out),
                        normalize_mask(Some(m)),
                    ))
                }
                other => bail!("window min/max over {} column", other.dtype()),
            }
        }
        other => bail!("cumulative frame cannot carry {other}"),
    }
}

/// One partition's (or the whole serial array's) window aggregate.
/// `order_breaks` carries the order-key change flags Rank needs (aligned to
/// the rows of `col`); other functions ignore it.
pub fn window_group(
    col: &Column,
    mask: Option<&ValidityMask>,
    frame: &WindowFrame,
    func: &WindowFunc,
    order_breaks: Option<&[bool]>,
) -> Result<NullableColumn> {
    match func {
        WindowFunc::RowNumber => Ok(NullableColumn::from_column(row_numbers(col.len(), 0))),
        WindowFunc::Rank => {
            let breaks =
                order_breaks.context("window rank(): order-key change flags missing")?;
            Ok(NullableColumn::from_column(rank_from_breaks(breaks)))
        }
        WindowFunc::Value => match frame {
            WindowFrame::Shift(k) => Ok(shift_window(col, mask, *k)),
            other => bail!("window value() requires a shift frame, got {other}"),
        },
        _ => match frame {
            WindowFrame::Rolling {
                preceding,
                following,
            } => rolling_window(col, mask, *preceding, *following, func),
            WindowFrame::CumulativeToCurrent => cumulative_window(col, mask, func),
            WindowFrame::Shift(_) => {
                bail!("window shift frame only carries value()")
            }
        },
    }
}

/// Stable argsort + partition-run boundaries over materialized key tuples
/// (`np` leading cells = partition keys, the rest = order keys): returns
/// `(sort index, group start positions, order-key change flags)` — the
/// shared sorting step of the exec partitioned lowering and the serial
/// baseline, so the break rule cannot diverge between engines.
pub fn partition_runs(
    krows: &[KeyRow],
    np: usize,
    orders: &[SortOrder],
) -> (Vec<usize>, Vec<usize>, Vec<bool>) {
    // dictionary-encoded fixed-width rows + radix argsort — stable and
    // byte-identical to a comparison sort of the tuples under `orders`
    let idx = SortKeys::from_key_rows(krows, orders).argsort();
    let mut group_starts: Vec<usize> = Vec::new();
    let mut breaks: Vec<bool> = Vec::with_capacity(idx.len());
    for (pos, &ri) in idx.iter().enumerate() {
        let new_group = pos == 0 || krows[idx[pos - 1]][..np] != krows[ri][..np];
        if new_group {
            group_starts.push(pos);
        }
        breaks.push(new_group || krows[idx[pos - 1]][np..] != krows[ri][np..]);
    }
    (idx, group_starts, breaks)
}

/// Apply one window aggregate independently over sorted partition runs:
/// `group_starts` are the ascending start indices of each run (first entry
/// 0 when rows exist); `order_breaks` spans all rows. The per-group results
/// are concatenated back in row order — the partitioned-exec and serial-
/// baseline shared kernel.
pub fn window_over_groups(
    col: &Column,
    mask: Option<&ValidityMask>,
    frame: &WindowFrame,
    func: &WindowFunc,
    group_starts: &[usize],
    order_breaks: Option<&[bool]>,
) -> Result<NullableColumn> {
    let n = col.len();
    let mut out = Column::new_empty(func.output_dtype(col.dtype()));
    let mut om: Option<ValidityMask> = None;
    for (gi, &start) in group_starts.iter().enumerate() {
        let end = group_starts.get(gi + 1).copied().unwrap_or(n);
        let sub = col.slice(start, end - start);
        let subm = mask.map(|m| m.slice(start, end - start));
        let breaks: Option<Vec<bool>> = order_breaks.map(|b| b[start..end].to_vec());
        let res = window_group(&sub, subm.as_ref(), frame, func, breaks.as_deref())?;
        let before = out.len();
        out.extend(&res.values);
        extend_opt_mask(&mut om, before, res.validity.as_ref(), res.values.len());
    }
    Ok(NullableColumn::new(out, normalize_mask(om)))
}

/// Distributed *global* window over this rank's contiguous block of a
/// globally ordered column. Rolling/shift frames exchange an asymmetric
/// halo with near neighbors (gather fallback when a block is smaller than
/// the frame reach); cumulative frames run local scans + `exscan`.
/// `statically_nullable` is the plan-schema nullability of the input
/// expression — a *global* fact every rank shares, used to pick code paths
/// without an extra collective.
pub fn window_1d(
    comm: &Comm,
    col: &Column,
    mask: Option<&ValidityMask>,
    frame: &WindowFrame,
    func: &WindowFunc,
    statically_nullable: bool,
) -> Result<NullableColumn> {
    if let WindowFunc::RowNumber = func {
        let start = comm.exscan_i64(col.len() as i64, ReduceOp::Sum);
        return Ok(NullableColumn::from_column(row_numbers(col.len(), start)));
    }
    if let WindowFunc::Rank = func {
        bail!("global rank() requires partition_by (rejected at plan typing)");
    }
    if comm.nranks() == 1 {
        return window_group(col, mask, frame, func, None);
    }
    match frame {
        WindowFrame::CumulativeToCurrent => cumulative_1d(comm, col, mask, func),
        WindowFrame::Rolling {
            preceding,
            following,
        } => {
            // historical stencil fast path: symmetric weighted window over a
            // statically non-nullable column rides the raw-f64 halo kernel,
            // bit-for-bit identical to the pre-Window `Plan::Stencil` output
            if let WindowFunc::Weighted(w) = func {
                if !statically_nullable && preceding == following {
                    return Ok(NullableColumn::from_column(Column::F64(stencil_1d(
                        comm,
                        &col.to_f64_vec(),
                        w,
                    ))));
                }
            }
            halo_window(comm, col, mask, *preceding, *following, frame, func)
        }
        WindowFrame::Shift(k) => {
            let (p, f) = frame.halo();
            if *k == 0 {
                return Ok(NullableColumn::new(
                    col.clone(),
                    mask.cloned(),
                ));
            }
            halo_window(comm, col, mask, p, f, frame, func)
        }
    }
}

/// Asymmetric halo exchange + padded serial kernel. The halo is exactly
/// `preceding` rows wide on every interior left boundary and `following`
/// rows on every interior right boundary, so frame truncation inside the
/// padded array coincides with *global* array edges — the stencil argument,
/// generalized.
fn halo_window(
    comm: &Comm,
    col: &Column,
    mask: Option<&ValidityMask>,
    preceding: usize,
    following: usize,
    frame: &WindowFrame,
    func: &WindowFunc,
) -> Result<NullableColumn> {
    let n = col.len();
    // blocks smaller than the frame reach cannot satisfy a 1-hop halo
    let min_len = comm.allreduce_i64(n as i64, ReduceOp::Min);
    if (min_len as usize) < preceding.max(following) {
        return gather_fallback(comm, col, mask, frame, func);
    }
    let encode_slice = |start: usize, len: usize| {
        let mut b = Vec::new();
        encode_nullable_column(
            &col.slice(start, len),
            mask.map(|m| m.slice(start, len)).as_ref(),
            &mut b,
        );
        b
    };
    // prev rank needs my first `following` rows; next rank my last `preceding`
    let send_prev = following.min(n);
    let send_next = preceding.min(n);
    let to_prev = encode_slice(0, send_prev);
    let to_next = encode_slice(n - send_next, send_next);
    let (from_prev, from_next) = comm.halo_exchange(to_prev, to_next);
    let decode = |b: Option<Vec<u8>>| -> Result<(Column, Option<ValidityMask>)> {
        match b {
            Some(buf) => {
                let mut pos = 0;
                decode_nullable_column(&buf, &mut pos)
            }
            None => Ok((Column::new_empty(col.dtype()), None)),
        }
    };
    let (left_col, left_mask) = decode(from_prev)?;
    let (right_col, right_mask) = decode(from_next)?;
    let left = left_col.len();

    // padded := [left halo | local | right halo]
    let mut padded = left_col;
    let mut padded_mask = left_mask;
    let before = padded.len();
    padded.extend(col);
    extend_opt_mask(&mut padded_mask, before, mask, n);
    let before = padded.len();
    padded.extend(&right_col);
    extend_opt_mask(&mut padded_mask, before, right_mask.as_ref(), right_col.len());

    let full = window_group(&padded, padded_mask.as_ref(), frame, func, None)?;
    let vals = full.values.slice(left, n);
    let m = full.validity.map(|m| m.slice(left, n));
    Ok(NullableColumn::new(vals, normalize_mask(m)))
}

/// Correctness-first fallback for tiny blocks: gather the whole (nullable)
/// column on the root, run the serial kernel, broadcast, slice.
fn gather_fallback(
    comm: &Comm,
    col: &Column,
    mask: Option<&ValidityMask>,
    frame: &WindowFrame,
    func: &WindowFunc,
) -> Result<NullableColumn> {
    let mut b = Vec::new();
    encode_nullable_column(col, mask, &mut b);
    let gathered = comm.gather_bytes(0, b);
    let mut out_buf = Vec::new();
    if comm.is_root() {
        let mut full = Column::new_empty(col.dtype());
        let mut full_mask: Option<ValidityMask> = None;
        for buf in &gathered {
            let mut pos = 0;
            let (c, m) = decode_nullable_column(buf, &mut pos)?;
            let before = full.len();
            full.extend(&c);
            extend_opt_mask(&mut full_mask, before, m.as_ref(), c.len());
        }
        let res = window_group(&full, full_mask.as_ref(), frame, func, None)?;
        encode_nullable_column(&res.values, res.validity.as_ref(), &mut out_buf);
    }
    let out_buf = comm.bcast_bytes(0, out_buf);
    let mut pos = 0;
    let (full_vals, full_mask) = decode_nullable_column(&out_buf, &mut pos)?;
    let off = comm.exscan_i64(col.len() as i64, ReduceOp::Sum) as usize;
    let vals = full_vals.slice(off, col.len());
    let m = full_mask.map(|m| m.slice(off, col.len()));
    Ok(NullableColumn::new(vals, normalize_mask(m)))
}

/// Distributed cumulative scans: local running reductions + one or two
/// `exscan` collectives. Every rank follows the same collective sequence
/// regardless of its local mask, so mixed-null rank sets stay in lockstep.
fn cumulative_1d(
    comm: &Comm,
    col: &Column,
    mask: Option<&ValidityMask>,
    func: &WindowFunc,
) -> Result<NullableColumn> {
    let n = col.len();
    match func {
        WindowFunc::Sum => match col {
            // mask-free sums ARE the paper's cumsum — delegate to the scan
            // kernels so the collective protocol lives in one place
            Column::I64(xs) => {
                if mask.is_none() {
                    return Ok(NullableColumn::from_column(Column::I64(cumsum_i64(
                        comm, xs,
                    ))));
                }
                let mut run = 0i64;
                let mut out = Vec::with_capacity(n);
                for (i, &x) in xs.iter().enumerate() {
                    if is_valid(mask, i) {
                        run += x;
                    }
                    out.push(run);
                }
                let off = comm.exscan_i64(run, ReduceOp::Sum);
                if off != 0 {
                    for v in &mut out {
                        *v += off;
                    }
                }
                Ok(NullableColumn::from_column(Column::I64(out)))
            }
            Column::F64(xs) => {
                if mask.is_none() {
                    return Ok(NullableColumn::from_column(Column::F64(cumsum_f64(
                        comm, xs,
                    ))));
                }
                let mut run = 0.0;
                let mut out = Vec::with_capacity(n);
                for (i, &x) in xs.iter().enumerate() {
                    if is_valid(mask, i) {
                        run += x;
                    }
                    out.push(run);
                }
                let off = comm.exscan_f64(run, ReduceOp::Sum);
                if off != 0.0 {
                    for v in &mut out {
                        *v += off;
                    }
                }
                Ok(NullableColumn::from_column(Column::F64(out)))
            }
            other => bail!("window sum over {} column", other.dtype()),
        },
        WindowFunc::Count => {
            let mut run = 0i64;
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                if is_valid(mask, i) {
                    run += 1;
                }
                out.push(run);
            }
            let off = comm.exscan_i64(run, ReduceOp::Sum);
            if off != 0 {
                for v in &mut out {
                    *v += off;
                }
            }
            Ok(NullableColumn::from_column(Column::I64(out)))
        }
        WindowFunc::Mean => {
            let xs = col.to_f64_vec();
            let mut sums = Vec::with_capacity(n);
            let mut cnts = Vec::with_capacity(n);
            let mut s = 0.0;
            let mut c = 0i64;
            for (i, &x) in xs.iter().enumerate() {
                if is_valid(mask, i) {
                    s += x;
                    c += 1;
                }
                sums.push(s);
                cnts.push(c);
            }
            let soff = comm.exscan_f64(s, ReduceOp::Sum);
            let coff = comm.exscan_i64(c, ReduceOp::Sum);
            let mut out = Vec::with_capacity(n);
            let mut m = ValidityMask::new_valid(n);
            for i in 0..n {
                let total_c = cnts[i] + coff;
                if total_c == 0 {
                    out.push(0.0);
                    m.set(i, false);
                } else {
                    out.push((sums[i] + soff) / total_c as f64);
                }
            }
            Ok(NullableColumn::new(
                Column::F64(out),
                normalize_mask(Some(m)),
            ))
        }
        WindowFunc::Min | WindowFunc::Max => {
            let want_min = matches!(func, WindowFunc::Min);
            let op = if want_min { ReduceOp::Min } else { ReduceOp::Max };
            // prior-rank state: (reduction over earlier ranks, their count)
            match col {
                Column::I64(xs) => {
                    let mut best: Option<i64> = None;
                    let mut run: Vec<Option<i64>> = Vec::with_capacity(n);
                    for (i, &x) in xs.iter().enumerate() {
                        if is_valid(mask, i) {
                            best = Some(match best {
                                None => x,
                                Some(b) if want_min => b.min(x),
                                Some(b) => b.max(x),
                            });
                        }
                        run.push(best);
                    }
                    let ident = if want_min { i64::MAX } else { i64::MIN };
                    let local_cnt = mask.map_or(n, |m| m.count_valid()) as i64;
                    let prev = comm.exscan_i64(best.unwrap_or(ident), op);
                    let prev_cnt = comm.exscan_i64(local_cnt, ReduceOp::Sum);
                    let mut out = Vec::with_capacity(n);
                    let mut m = ValidityMask::new_valid(n);
                    for (i, b) in run.iter().enumerate() {
                        let v = match (prev_cnt > 0, b) {
                            (true, Some(b)) => Some(if want_min {
                                prev.min(*b)
                            } else {
                                prev.max(*b)
                            }),
                            (true, None) => Some(prev),
                            (false, Some(b)) => Some(*b),
                            (false, None) => None,
                        };
                        match v {
                            Some(v) => out.push(v),
                            None => {
                                out.push(0);
                                m.set(i, false);
                            }
                        }
                    }
                    Ok(NullableColumn::new(
                        Column::I64(out),
                        normalize_mask(Some(m)),
                    ))
                }
                Column::F64(xs) => {
                    let mut best: Option<f64> = None;
                    let mut run: Vec<Option<f64>> = Vec::with_capacity(n);
                    for (i, &x) in xs.iter().enumerate() {
                        if is_valid(mask, i) {
                            best = Some(match best {
                                None => x,
                                Some(b) if want_min => b.min(x),
                                Some(b) => b.max(x),
                            });
                        }
                        run.push(best);
                    }
                    let ident = if want_min {
                        f64::INFINITY
                    } else {
                        f64::NEG_INFINITY
                    };
                    let local_cnt = mask.map_or(n, |m| m.count_valid()) as i64;
                    let prev = comm.exscan_f64(best.unwrap_or(ident), op);
                    let prev_cnt = comm.exscan_i64(local_cnt, ReduceOp::Sum);
                    let mut out = Vec::with_capacity(n);
                    let mut m = ValidityMask::new_valid(n);
                    for (i, b) in run.iter().enumerate() {
                        let v = match (prev_cnt > 0, b) {
                            (true, Some(b)) => Some(if want_min {
                                prev.min(*b)
                            } else {
                                prev.max(*b)
                            }),
                            (true, None) => Some(prev),
                            (false, Some(b)) => Some(*b),
                            (false, None) => None,
                        };
                        match v {
                            Some(v) => out.push(v),
                            None => {
                                out.push(0.0);
                                m.set(i, false);
                            }
                        }
                    }
                    Ok(NullableColumn::new(
                        Column::F64(out),
                        normalize_mask(Some(m)),
                    ))
                }
                other => bail!("window min/max over {} column", other.dtype()),
            }
        }
        other => bail!("cumulative frame cannot carry {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{block_range, run_spmd};
    use crate::ops::stencil::{sma_weights, stencil_serial, wma_weights_124};

    fn masked(xs: Vec<i64>, nulls: &[usize]) -> (Column, Option<ValidityMask>) {
        let n = xs.len();
        let mut m = ValidityMask::new_valid(n);
        for &i in nulls {
            m.set(i, false);
        }
        (Column::I64(xs), normalize_mask(Some(m)))
    }

    #[test]
    fn rolling_sum_mean_min_serial() {
        let (c, m) = masked(vec![1, 2, 3, 4, 5], &[2]);
        let s = rolling_window(&c, m.as_ref(), 1, 1, &WindowFunc::Sum).unwrap();
        // windows: [1,2]=3, [1,2,_]=3, [2,_,4]=6, [_,4,5]=9, [4,5]=9
        assert_eq!(s.values.as_i64(), &[3, 3, 6, 9, 9]);
        assert!(s.validity.is_none());
        let mn = rolling_window(&c, m.as_ref(), 1, 1, &WindowFunc::Min).unwrap();
        assert_eq!(mn.values.as_i64(), &[1, 1, 2, 4, 4]);
        let cnt = rolling_window(&c, m.as_ref(), 1, 1, &WindowFunc::Count).unwrap();
        assert_eq!(cnt.values.as_i64(), &[2, 2, 2, 2, 2]);
        let mean = rolling_window(&c, m.as_ref(), 1, 1, &WindowFunc::Mean).unwrap();
        assert!((mean.values.as_f64()[2] - 3.0).abs() < 1e-12); // (2+4)/2
    }

    #[test]
    fn rolling_all_null_window_goes_null() {
        let (c, m) = masked(vec![7, 8, 9], &[0, 1, 2]);
        let mean = rolling_window(&c, m.as_ref(), 1, 0, &WindowFunc::Mean).unwrap();
        assert_eq!(mean.null_count(), 3);
        let s = rolling_window(&c, m.as_ref(), 1, 0, &WindowFunc::Sum).unwrap();
        assert!(s.validity.is_none());
        assert_eq!(s.values.as_i64(), &[0, 0, 0]);
    }

    #[test]
    fn weighted_matches_stencil_serial_on_valid_input() {
        let xs: Vec<f64> = (0..23).map(|i| ((i * 7) % 5) as f64 - 1.5).collect();
        let c = Column::F64(xs.clone());
        for w in [sma_weights(3), wma_weights_124(), sma_weights(5)] {
            let r = w.len() / 2;
            let got = rolling_window(&c, None, r, r, &WindowFunc::Weighted(w.clone())).unwrap();
            let expect = stencil_serial(&xs, &w);
            assert_eq!(got.values.as_f64(), expect.as_slice());
            assert!(got.validity.is_none());
        }
    }

    #[test]
    fn shift_serial_edges_null() {
        let (c, m) = masked(vec![10, 20, 30, 40], &[1]);
        let lag = shift_window(&c, m.as_ref(), 1);
        assert_eq!(lag.values.as_i64(), &[0, 10, 0, 30]);
        assert_eq!(
            lag.validity.unwrap().to_bools(),
            vec![false, true, false, true]
        );
        let lead = shift_window(&c, m.as_ref(), -2);
        assert_eq!(lead.values.as_i64(), &[30, 40, 0, 0]);
        assert_eq!(
            lead.validity.unwrap().to_bools(),
            vec![true, true, false, false]
        );
    }

    #[test]
    fn cumulative_serial_null_skip() {
        let (c, m) = masked(vec![1, 2, 3, 4], &[0, 2]);
        let s = cumulative_window(&c, m.as_ref(), &WindowFunc::Sum).unwrap();
        assert_eq!(s.values.as_i64(), &[0, 2, 2, 6]);
        let mean = cumulative_window(&c, m.as_ref(), &WindowFunc::Mean).unwrap();
        assert!(!mean.is_valid(0)); // nothing valid yet
        assert!((mean.values.as_f64()[3] - 3.0).abs() < 1e-12); // (2+4)/2
        let mx = cumulative_window(&c, m.as_ref(), &WindowFunc::Max).unwrap();
        assert_eq!(mx.values.as_i64()[3], 4);
        assert!(!mx.is_valid(0));
    }

    #[test]
    fn rank_and_row_number() {
        assert_eq!(
            rank_from_breaks(&[true, false, true, false, true]).as_i64(),
            &[1, 1, 3, 3, 5]
        );
        assert_eq!(row_numbers(3, 10).as_i64(), &[11, 12, 13]);
    }

    #[test]
    fn grouped_windows_respect_boundaries() {
        // two groups: [0..3) and [3..6); shift must not leak across them
        let c = Column::I64(vec![1, 2, 3, 10, 20, 30]);
        let out = window_over_groups(
            &c,
            None,
            &WindowFrame::Shift(1),
            &WindowFunc::Value,
            &[0, 3],
            None,
        )
        .unwrap();
        assert_eq!(out.values.as_i64(), &[0, 1, 2, 0, 10, 20]);
        assert_eq!(
            out.validity.unwrap().to_bools(),
            vec![false, true, true, false, true, true]
        );
        let cs = window_over_groups(
            &c,
            None,
            &WindowFrame::CumulativeToCurrent,
            &WindowFunc::Sum,
            &[0, 3],
            None,
        )
        .unwrap();
        assert_eq!(cs.values.as_i64(), &[1, 3, 6, 10, 30, 60]);
    }

    fn spmd_window(
        p: usize,
        xs: &[i64],
        nulls: &[usize],
        frame: WindowFrame,
        func: WindowFunc,
    ) -> NullableColumn {
        let (full, full_mask) = masked(xs.to_vec(), nulls);
        let statically_nullable = !nulls.is_empty();
        let out = run_spmd(p, |c| {
            let (s, l) = block_range(xs.len(), p, c.rank());
            let col = full.slice(s, l);
            let m = normalize_mask(full_mask.as_ref().map(|m| m.slice(s, l)));
            window_1d(&c, &col, m.as_ref(), &frame, &func, statically_nullable).unwrap()
        });
        let mut vals = Column::new_empty(out[0].values.dtype());
        let mut m: Option<ValidityMask> = None;
        for part in out {
            let before = vals.len();
            vals.extend(&part.values);
            extend_opt_mask(&mut m, before, part.validity.as_ref(), part.values.len());
        }
        NullableColumn::new(vals, normalize_mask(m))
    }

    #[test]
    fn distributed_matches_serial_all_funcs() {
        let xs: Vec<i64> = (0..37).map(|i| (i * 13) % 11 - 5).collect();
        let nulls: Vec<usize> = (0..37).filter(|i| i % 5 == 0).collect();
        let (full, full_mask) = masked(xs.clone(), &nulls);
        let cases: Vec<(WindowFrame, WindowFunc)> = vec![
            (
                WindowFrame::Rolling {
                    preceding: 2,
                    following: 1,
                },
                WindowFunc::Sum,
            ),
            (
                WindowFrame::Rolling {
                    preceding: 1,
                    following: 1,
                },
                WindowFunc::Mean,
            ),
            (
                WindowFrame::Rolling {
                    preceding: 3,
                    following: 0,
                },
                WindowFunc::Min,
            ),
            (
                WindowFrame::Rolling {
                    preceding: 0,
                    following: 2,
                },
                WindowFunc::Max,
            ),
            (
                WindowFrame::Rolling {
                    preceding: 2,
                    following: 2,
                },
                WindowFunc::Count,
            ),
            (WindowFrame::CumulativeToCurrent, WindowFunc::Sum),
            (WindowFrame::CumulativeToCurrent, WindowFunc::Mean),
            (WindowFrame::CumulativeToCurrent, WindowFunc::Min),
            (WindowFrame::CumulativeToCurrent, WindowFunc::Max),
            (WindowFrame::CumulativeToCurrent, WindowFunc::Count),
            (WindowFrame::Shift(2), WindowFunc::Value),
            (WindowFrame::Shift(-3), WindowFunc::Value),
        ];
        for (frame, func) in cases {
            let expect = window_group(&full, full_mask.as_ref(), &frame, &func, None).unwrap();
            for p in [1usize, 2, 4] {
                let got = spmd_window(p, &xs, &nulls, frame.clone(), func.clone());
                assert_eq!(
                    got.values, expect.values,
                    "{frame} {func} p={p} values"
                );
                assert_eq!(
                    got.validity, expect.validity,
                    "{frame} {func} p={p} masks"
                );
            }
        }
    }

    #[test]
    fn distributed_weighted_matches_stencil() {
        let xs: Vec<i64> = (0..29).map(|i| (i * 7) % 13).collect();
        let w = wma_weights_124();
        let expect = stencil_serial(
            &xs.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &w,
        );
        for p in [1usize, 2, 3] {
            let got = spmd_window(
                p,
                &xs,
                &[],
                WindowFrame::Rolling {
                    preceding: 1,
                    following: 1,
                },
                WindowFunc::Weighted(w.clone()),
            );
            assert_eq!(got.values.as_f64(), expect.as_slice(), "p={p}");
        }
    }

    #[test]
    fn tiny_blocks_take_gather_fallback() {
        // 5 rows on 4 ranks with a frame reaching 3 back → fallback path
        let xs = vec![5i64, 1, 4, 2, 3];
        let nulls = vec![1usize];
        let (full, full_mask) = masked(xs.clone(), &nulls);
        let frame = WindowFrame::Rolling {
            preceding: 3,
            following: 0,
        };
        let expect =
            window_group(&full, full_mask.as_ref(), &frame, &WindowFunc::Min, None).unwrap();
        let got = spmd_window(4, &xs, &nulls, frame, WindowFunc::Min);
        assert_eq!(got.values, expect.values);
        assert_eq!(got.validity, expect.validity);
    }

    #[test]
    fn distributed_row_number() {
        let out = run_spmd(3, |c| {
            let (s, l) = block_range(10, 3, c.rank());
            let col = Column::I64(vec![0; l]);
            let _ = s;
            window_1d(
                &c,
                &col,
                None,
                &WindowFrame::CumulativeToCurrent,
                &WindowFunc::RowNumber,
                false,
            )
            .unwrap()
        });
        let got: Vec<i64> = out
            .iter()
            .flat_map(|nc| nc.values.as_i64().to_vec())
            .collect();
        assert_eq!(got, (1..=10).collect::<Vec<i64>>());
    }
}
