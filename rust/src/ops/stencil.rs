//! 1-D stencils — SMA and WMA (paper §3.1, Table 1; §4.5: "stencils of
//! HiFrames generate near neighbor communication and the associated border
//! handling").
//!
//! Window semantics (shared by the serial oracle, the SPMD kernel, the
//! baseline engines, `ref.py` and the Pallas kernel): radius `r =
//! weights.len()/2`; interior points get `Σ w[j]·x[i+j-r]`; points within
//! `r` of a *global* edge use the truncated window, renormalized by the
//! weight mass actually used:
//!
//! ```text
//!   out[i] = (Σ_valid w·x) · (Σ_all w) / (Σ_valid w)
//! ```

use crate::comm::Comm;

/// Serial oracle (also the Pandas/Julia baseline implementation).
pub fn stencil_serial(xs: &[f64], weights: &[f64]) -> Vec<f64> {
    assert!(weights.len() % 2 == 1, "stencil: odd window only");
    let r = weights.len() / 2;
    let wtotal: f64 = weights.iter().sum();
    let n = xs.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut acc = 0.0;
        let mut used = 0.0;
        for (j, &w) in weights.iter().enumerate() {
            let idx = i as isize + j as isize - r as isize;
            if idx >= 0 && (idx as usize) < n {
                acc += w * xs[idx as usize];
                used += w;
            }
        }
        out.push(if used != 0.0 { acc * wtotal / used } else { 0.0 });
    }
    out
}

/// Distributed stencil over this rank's contiguous block. Halo cells are
/// exchanged with near neighbors (the paper's `MPI_Isend/Irecv/Wait`
/// pattern). Requires `1D_BLOCK` input — the Distributed-Pass inserts a
/// rebalance upstream when needed; tiny blocks (< radius) trigger a gather
/// fallback that keeps the semantics exact.
pub fn stencil_1d(comm: &Comm, local: &[f64], weights: &[f64]) -> Vec<f64> {
    assert!(weights.len() % 2 == 1, "stencil: odd window only");
    let r = weights.len() / 2;
    if comm.nranks() == 1 || r == 0 {
        return stencil_serial(local, weights);
    }

    // blocks smaller than the radius cannot satisfy a 1-hop halo; fall back
    // to gather-on-root (correctness first; never hit after rebalance on
    // realistic sizes)
    let min_len = comm.allreduce_i64(local.len() as i64, crate::comm::ReduceOp::Min);
    if (min_len as usize) < r {
        return stencil_gather_fallback(comm, local, weights);
    }

    // exchange r boundary elements with each neighbor
    let to_prev: Vec<u8> = pack(&local[..r.min(local.len())]);
    let to_next: Vec<u8> = pack(&local[local.len().saturating_sub(r)..]);
    let (from_prev, from_next) = comm.halo_exchange(to_prev, to_next);
    let left: Vec<f64> = from_prev.map(|b| unpack(&b)).unwrap_or_default();
    let right: Vec<f64> = from_next.map(|b| unpack(&b)).unwrap_or_default();

    // padded := [left halo | local | right halo]
    let mut padded = Vec::with_capacity(left.len() + local.len() + right.len());
    padded.extend_from_slice(&left);
    padded.extend_from_slice(local);
    padded.extend_from_slice(&right);

    let wtotal: f64 = weights.iter().sum();
    let n = padded.len();
    let off = left.len();
    let mut out = Vec::with_capacity(local.len());
    for i in 0..local.len() {
        let pi = i + off;
        let mut acc = 0.0;
        let mut used = 0.0;
        for (j, &w) in weights.iter().enumerate() {
            let idx = pi as isize + j as isize - r as isize;
            // idx out of `padded` ⇔ out of the *global* array because the
            // halo is exactly r wide on every interior boundary
            if idx >= 0 && (idx as usize) < n {
                acc += w * padded[idx as usize];
                used += w;
            }
        }
        out.push(if used != 0.0 { acc * wtotal / used } else { 0.0 });
    }
    out
}

fn stencil_gather_fallback(comm: &Comm, local: &[f64], weights: &[f64]) -> Vec<f64> {
    let gathered = comm.gather_bytes(0, pack(local));
    let full: Vec<f64> = if comm.is_root() {
        let all: Vec<f64> = gathered.iter().flat_map(|b| unpack(b)).collect();
        stencil_serial(&all, weights)
    } else {
        Vec::new()
    };
    // scatter results back by broadcasting and slicing (simple + correct)
    let full = comm.bcast_bytes(0, pack(&full));
    let full = unpack(&full);
    // my global offset = exscan of my local length
    let off = comm.exscan_i64(local.len() as i64, crate::comm::ReduceOp::Sum) as usize;
    full[off..off + local.len()].to_vec()
}

fn pack(xs: &[f64]) -> Vec<u8> {
    let mut b = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        b.extend_from_slice(&x.to_le_bytes());
    }
    b
}

fn unpack(b: &[u8]) -> Vec<f64> {
    b.chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// The SMA window of width `w` (equal weights summing to 1).
pub fn sma_weights(w: usize) -> Vec<f64> {
    assert!(w % 2 == 1);
    vec![1.0 / w as f64; w]
}

/// The paper's WMA example: `(x[-1] + 2x[0] + x[1]) / 4`.
pub fn wma_weights_124() -> Vec<f64> {
    vec![0.25, 0.5, 0.25]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{block_range, run_spmd};

    #[test]
    fn serial_interior_matches_formula() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let out = stencil_serial(&xs, &sma_weights(3));
        // interior: plain moving average
        assert!((out[1] - 2.0).abs() < 1e-12);
        assert!((out[2] - 3.0).abs() < 1e-12);
        // edges: truncated + renormalized → mean of available neighbors
        assert!((out[0] - 1.5).abs() < 1e-12);
        assert!((out[4] - 4.5).abs() < 1e-12);
    }

    #[test]
    fn serial_wma_paper_example() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        let out = stencil_serial(&xs, &wma_weights_124());
        // interior i=1: (1 + 2*2 + 3)/4 = 2
        assert!((out[1] - 2.0).abs() < 1e-12);
        assert!((out[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn distributed_matches_serial() {
        let xs: Vec<f64> = (0..41).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
        for weights in [sma_weights(3), wma_weights_124(), sma_weights(5)] {
            let expect = stencil_serial(&xs, &weights);
            for p in [1usize, 2, 4] {
                let out = run_spmd(p, |c| {
                    let (s, l) = block_range(xs.len(), p, c.rank());
                    stencil_1d(&c, &xs[s..s + l], &weights)
                });
                let got: Vec<f64> = out.into_iter().flatten().collect();
                assert_eq!(got.len(), expect.len());
                for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
                    assert!(
                        (g - e).abs() < 1e-9,
                        "w={weights:?} p={p} i={i}: {g} vs {e}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiny_blocks_fallback() {
        // 5 elements on 4 ranks with radius 2 → some blocks < r, fallback path
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let weights = sma_weights(5);
        let expect = stencil_serial(&xs, &weights);
        let out = run_spmd(4, |c| {
            let (s, l) = block_range(xs.len(), 4, c.rank());
            stencil_1d(&c, &xs[s..s + l], &weights)
        });
        let got: Vec<f64> = out.into_iter().flatten().collect();
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-9);
        }
    }

    #[test]
    fn width_one_is_identity() {
        let xs = vec![3.0, 1.0, 4.0];
        assert_eq!(stencil_serial(&xs, &[1.0]), xs);
    }
}
