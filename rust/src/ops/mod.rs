//! Distributed relational & analytics operators — the runtime the paper's
//! CGen emits (§4.5), one module per communication pattern:
//!
//! * [`shuffle`] — hash-partition + `alltoallv` (join/aggregate prologue;
//!   the paper's Fig. 5 `_df_id[i] % npes` packing loop, generalized to
//!   packed composite-key routing in [`shuffle::shuffle_by_packed`]).
//! * [`keys`] — composite keys: the packed fast path ([`keys::PackedKeys`],
//!   [`keys::SortKeys`]) plus the materialized [`keys::KeyRow`] tuples used
//!   at the API boundary, on the wire, and by the baseline engines.
//! * [`join`] — post-shuffle hash join over packed keys with
//!   Inner/Left/Right/Outer/Semi/Anti semantics (plus the seed's single-key
//!   sort-merge kernel and the KeyRow hash join as oracles), and the
//!   skew-aware broadcast path that splits heavy-hitter keys out of the
//!   shuffle.
//! * [`skew`] — distributed heavy-hitter detection: per-rank key sampling
//!   merged through one allgather into a globally agreed [`skew::HeavySet`].
//! * [`aggregate`] — post-shuffle hash aggregation over packed key groups,
//!   with optional local pre-aggregation (decomposed partial states).
//! * [`scan`] — cumulative sum via local partials + `exscan`.
//! * [`stencil`] — SMA/WMA windows via near-neighbor halo exchange.
//! * [`window`] — the generalized window-function runtime
//!   ([`crate::ir::Plan::Window`]): rolling/shift frames via asymmetric
//!   halo exchange (reusing the stencil internals), cumulative frames via
//!   `exscan`, plus the per-partition grouped kernels the partitioned
//!   shuffle path scans with.
//! * [`rebalance`] — `1D_VAR` → `1D_BLOCK` redistribution preserving global
//!   row order.
//! * [`sort`] — sample-sort global ordering (result canonicalization,
//!   TPCx-BB top-N steps).
//! * [`spill`] — out-of-core substrate: per-rank memory budgets, hash
//!   partitioning to disk over the codec wire format, spill-file lifecycle.
//!   Join, aggregate and sort fall back to grace partitioning / external
//!   merge when their working set exceeds the budget.

pub mod aggregate;
pub mod join;
pub mod keys;
pub mod rebalance;
pub mod scan;
pub mod shuffle;
pub mod skew;
pub mod sort;
pub mod spill;
pub mod stencil;
pub mod window;

pub use aggregate::{
    agg_output_nullable, distributed_aggregate, distributed_aggregate_keys,
    distributed_aggregate_keys_budgeted, local_hash_aggregate_keys, local_packed_aggregate,
};
pub use join::{
    distributed_join, distributed_join_on, distributed_join_on_budgeted,
    distributed_join_on_strategy, local_join_pairs, local_sort_merge_join, packed_join_pairs,
    packed_join_pairs_partial, MaskedCol,
};
pub use keys::{group_packed, KeyGroups, KeyNullability, KeyRow, KeyVal, PackedKeys, SortKeys};
pub use rebalance::{rebalance_block, rebalance_block_nullable};
pub use scan::{cumsum_f64, cumsum_i64};
pub use shuffle::{
    shuffle_by_key, shuffle_by_owner, shuffle_by_owner_nullable, shuffle_by_packed,
    shuffle_by_packed_nullable, shuffle_rows_by_owner_nullable,
};
pub use skew::{detect_heavy_hitters, HeavySet};
pub use sort::{distributed_sort_by_key, distributed_sort_keys, distributed_sort_keys_budgeted};
pub use spill::{MemoryBudget, PartitionStore, SpillCtx, SpillFile, MAX_SPILL_DEPTH};
pub use stencil::{stencil_1d, stencil_serial};
pub use window::{
    partition_runs, rank_from_breaks, row_numbers, shift_window, window_1d, window_group,
    window_over_groups,
};
