//! Distributed relational & analytics operators — the runtime the paper's
//! CGen emits (§4.5), one module per communication pattern:
//!
//! * [`shuffle`] — hash-partition + `alltoallv` (join/aggregate prologue;
//!   the paper's Fig. 5 `_df_id[i] % npes` packing loop).
//! * [`join`] — post-shuffle sort-merge join (Timsort-family stable sort,
//!   matching the paper's choice).
//! * [`aggregate`] — post-shuffle hash aggregation, with optional local
//!   pre-aggregation (decomposed partial states).
//! * [`scan`] — cumulative sum via local partials + `exscan`.
//! * [`stencil`] — SMA/WMA windows via near-neighbor halo exchange.
//! * [`rebalance`] — `1D_VAR` → `1D_BLOCK` redistribution preserving global
//!   row order.
//! * [`sort`] — sample-sort global ordering (result canonicalization,
//!   TPCx-BB top-N steps).

pub mod aggregate;
pub mod join;
pub mod rebalance;
pub mod scan;
pub mod shuffle;
pub mod sort;
pub mod stencil;

pub use aggregate::distributed_aggregate;
pub use join::{local_sort_merge_join, distributed_join};
pub use rebalance::rebalance_block;
pub use scan::{cumsum_f64, cumsum_i64};
pub use shuffle::shuffle_by_key;
pub use sort::distributed_sort_by_key;
pub use stencil::{stencil_1d, stencil_serial};
