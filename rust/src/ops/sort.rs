//! Distributed sort over composite keys with per-key directions:
//! sample-sort (local sort → regular sampling → splitter broadcast → range
//! partition `alltoallv` → local merge). Used for global result
//! canonicalization and TPCx-BB's multi-column ORDER BY steps. Output
//! distribution: `1D_VAR` (range partitions are data dependent — the
//! motivating case for the paper's 1D_VAR).
//!
//! Int64/Bool key lists take the packed fast path ([`SortKeys`]):
//! direction-aware fixed-width byte rows where every comparison — local
//! sort, splitter selection, range partition — is a `memcmp`, and splitters
//! travel as raw packed rows. Key lists containing String columns fall back
//! to materialized [`KeyRow`] tuples shipped through the [`keys`] wire
//! codec, ordered by [`cmp_key_rows`].

use super::keys::{self, cmp_key_rows, decode_key_row, encode_key_row, KeyRow, SortKeys};
use crate::column::{decode_column, encode_column, Column};
use crate::comm::Comm;
use crate::types::SortOrder;
use anyhow::{bail, Result};
use std::cmp::Ordering;

/// Sort `(key_cols, payload)` globally by the key tuples under `orders`
/// (one direction per key column). Rank r ends up holding the r-th range of
/// the sorted order (contiguous, 1D_VAR). Returns the sorted key columns
/// (dtypes preserved) and payload columns.
pub fn distributed_sort_keys(
    comm: &Comm,
    key_cols: &[&Column],
    orders: &[SortOrder],
    payload: &[&Column],
) -> Result<(Vec<Column>, Vec<Column>)> {
    if key_cols.is_empty() {
        bail!("sort: key column list must be non-empty");
    }
    if let Some(sk) = SortKeys::pack(key_cols, orders)? {
        return sort_packed(comm, sk, key_cols, orders, payload);
    }
    let p = comm.nranks();
    let krows = keys::key_rows(key_cols)?;
    // local sort (stable — Timsort-family, as in the paper)
    let mut idx: Vec<usize> = (0..krows.len()).collect();
    idx.sort_by(|&a, &b| cmp_key_rows(&krows[a], &krows[b], orders));
    let skrows: Vec<KeyRow> = idx.iter().map(|&i| krows[i].clone()).collect();
    let skey_cols: Vec<Column> = key_cols.iter().map(|c| c.take(&idx)).collect();
    let spay: Vec<Column> = payload.iter().map(|c| c.take(&idx)).collect();

    if p == 1 {
        return Ok((skey_cols, spay));
    }

    // regular sampling: p sample tuples per non-empty rank → root picks
    // p-1 splitter tuples
    let mut sample_buf = Vec::new();
    if !skrows.is_empty() {
        for s in 0..p {
            let pos = ((s * skrows.len()) / p).min(skrows.len() - 1);
            encode_key_row(&skrows[pos], &mut sample_buf);
        }
    }
    let gathered = comm.gather_bytes(0, sample_buf);
    let mut splitter_buf = Vec::new();
    if comm.is_root() {
        let mut all: Vec<KeyRow> = Vec::new();
        for buf in &gathered {
            let mut pos = 0;
            while pos < buf.len() {
                all.push(decode_key_row(key_cols.len(), buf, &mut pos)?);
            }
        }
        all.sort_by(|a, b| cmp_key_rows(a, b, orders));
        if !all.is_empty() {
            for i in 1..p {
                let pos = ((i * all.len()) / p).min(all.len() - 1);
                encode_key_row(&all[pos], &mut splitter_buf);
            }
        }
        // nothing to sort anywhere → broadcast zero splitters; every rank's
        // (empty) data trivially lands in bucket 0
    }
    let splitter_buf = comm.bcast_bytes(0, splitter_buf);
    let mut splitters: Vec<KeyRow> = Vec::new();
    {
        let mut pos = 0;
        while pos < splitter_buf.len() {
            splitters.push(decode_key_row(key_cols.len(), &splitter_buf, &mut pos)?);
        }
    }

    // range partition: dst = #splitters ≤ key (upper_bound under `orders`)
    let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
    let mut start = 0usize;
    for dst in 0..p {
        let end = if dst < splitters.len() {
            start
                + skrows[start..].partition_point(|k| {
                    cmp_key_rows(k, &splitters[dst], orders) != Ordering::Greater
                })
        } else {
            skrows.len()
        };
        if end > start {
            let buf = &mut bufs[dst];
            for c in &skey_cols {
                encode_column(&c.slice(start, end - start), buf);
            }
            for c in &spay {
                encode_column(&c.slice(start, end - start), buf);
            }
        }
        start = end;
        if start >= skrows.len() {
            break;
        }
    }
    let received = comm.alltoallv_bytes(bufs);

    // collect received runs and merge by one final local sort (runs are
    // sorted; a k-way merge is a §Perf refinement that measured <5% here)
    let mut rkeys: Vec<Column> = key_cols
        .iter()
        .map(|c| Column::new_empty(c.dtype()))
        .collect();
    let mut rpay: Vec<Column> = payload
        .iter()
        .map(|c| Column::new_empty(c.dtype()))
        .collect();
    for buf in received {
        if buf.is_empty() {
            continue;
        }
        let mut pos = 0;
        for oc in rkeys.iter_mut() {
            let c = decode_column(&buf, &mut pos)?;
            oc.extend(&c);
        }
        for oc in rpay.iter_mut() {
            let c = decode_column(&buf, &mut pos)?;
            oc.extend(&c);
        }
    }
    let rrows = keys::key_rows(&rkeys.iter().collect::<Vec<_>>())?;
    let mut idx: Vec<usize> = (0..rrows.len()).collect();
    idx.sort_by(|&a, &b| cmp_key_rows(&rrows[a], &rrows[b], orders));
    let fkeys: Vec<Column> = rkeys.iter().map(|c| c.take(&idx)).collect();
    let fpay: Vec<Column> = rpay.iter().map(|c| c.take(&idx)).collect();
    Ok((fkeys, fpay))
}

/// Packed sample-sort (Int64/Bool keys): every ordering decision is a byte
/// comparison of fixed-width direction-aware rows, and splitters are shipped
/// as raw packed rows — no tuple materialization, no per-cell wire codec.
fn sort_packed(
    comm: &Comm,
    sk: SortKeys,
    key_cols: &[&Column],
    orders: &[SortOrder],
    payload: &[&Column],
) -> Result<(Vec<Column>, Vec<Column>)> {
    let p = comm.nranks();
    let n = sk.len();
    // local argsort (stable — Timsort-family, as in the paper)
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| sk.row(a).cmp(sk.row(b)));
    let skey_cols: Vec<Column> = key_cols.iter().map(|c| c.take(&idx)).collect();
    let spay: Vec<Column> = payload.iter().map(|c| c.take(&idx)).collect();

    if p == 1 {
        return Ok((skey_cols, spay));
    }
    let ssk = sk.take(&idx);
    let w = ssk.width();

    // regular sampling: p packed sample rows per non-empty rank → root
    // picks p-1 splitter rows (raw bytes; width is schema-determined, so
    // every rank slices the broadcast identically)
    let mut sample_buf = Vec::new();
    if n > 0 {
        for s in 0..p {
            let pos = ((s * n) / p).min(n - 1);
            sample_buf.extend_from_slice(ssk.row(pos));
        }
    }
    let gathered = comm.gather_bytes(0, sample_buf);
    let mut splitter_buf = Vec::new();
    if comm.is_root() {
        let mut all: Vec<&[u8]> = Vec::new();
        for buf in &gathered {
            for chunk in buf.chunks_exact(w) {
                all.push(chunk);
            }
        }
        all.sort();
        if !all.is_empty() {
            for i in 1..p {
                let pos = ((i * all.len()) / p).min(all.len() - 1);
                splitter_buf.extend_from_slice(all[pos]);
            }
        }
        // nothing to sort anywhere → broadcast zero splitters; every rank's
        // (empty) data trivially lands in bucket 0
    }
    let splitter_buf = comm.bcast_bytes(0, splitter_buf);
    let splitters: Vec<&[u8]> = splitter_buf.chunks_exact(w).collect();

    // range partition: dst = #splitters ≤ row (upper_bound via memcmp)
    let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
    let mut start = 0usize;
    for dst in 0..p {
        let end = if dst < splitters.len() {
            start + ssk.partition_le(start, splitters[dst])
        } else {
            n
        };
        if end > start {
            let buf = &mut bufs[dst];
            for c in &skey_cols {
                encode_column(&c.slice(start, end - start), buf);
            }
            for c in &spay {
                encode_column(&c.slice(start, end - start), buf);
            }
        }
        start = end;
        if start >= n {
            break;
        }
    }
    let received = comm.alltoallv_bytes(bufs);

    // collect received runs and merge by one final packed local sort
    let mut rkeys: Vec<Column> = key_cols
        .iter()
        .map(|c| Column::new_empty(c.dtype()))
        .collect();
    let mut rpay: Vec<Column> = payload
        .iter()
        .map(|c| Column::new_empty(c.dtype()))
        .collect();
    for buf in received {
        if buf.is_empty() {
            continue;
        }
        let mut pos = 0;
        for oc in rkeys.iter_mut() {
            let c = decode_column(&buf, &mut pos)?;
            oc.extend(&c);
        }
        for oc in rpay.iter_mut() {
            let c = decode_column(&buf, &mut pos)?;
            oc.extend(&c);
        }
    }
    let rrefs: Vec<&Column> = rkeys.iter().collect();
    let rsk = SortKeys::pack(&rrefs, orders)?.expect("Int64/Bool keys stay packable");
    let mut idx: Vec<usize> = (0..rsk.len()).collect();
    idx.sort_by(|&a, &b| rsk.row(a).cmp(rsk.row(b)));
    let fkeys: Vec<Column> = rkeys.iter().map(|c| c.take(&idx)).collect();
    let fpay: Vec<Column> = rpay.iter().map(|c| c.take(&idx)).collect();
    Ok((fkeys, fpay))
}

/// Sort `(keys, cols)` globally ascending by a single i64 key — the seed
/// API, kept as a wrapper over [`distributed_sort_keys`].
pub fn distributed_sort_by_key(
    comm: &Comm,
    keys: &[i64],
    cols: &[Column],
) -> Result<(Vec<i64>, Vec<Column>)> {
    let kc = Column::I64(keys.to_vec());
    let crefs: Vec<&Column> = cols.iter().collect();
    let (kcols, pay) = distributed_sort_keys(comm, &[&kc], &[SortOrder::Asc], &crefs)?;
    Ok((kcols[0].as_i64().to_vec(), pay))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{block_range, run_spmd};
    use crate::datagen::Rng;

    #[test]
    fn sorts_globally() {
        let mut rng = Rng::new(11);
        let data: Vec<i64> = (0..97).map(|_| rng.i64_range(-50, 50)).collect();
        for p in [1usize, 2, 4] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(data.len(), p, c.rank());
                let keys = &data[s..s + l];
                let vals = Column::I64(keys.iter().map(|&k| k * 2).collect());
                let (k, cols) = distributed_sort_by_key(&c, keys, &[vals]).unwrap();
                (k, cols[0].as_i64().to_vec())
            });
            // concatenated ranks must be globally sorted
            let got: Vec<i64> = out.iter().flat_map(|(k, _)| k.clone()).collect();
            let mut expect = data.clone();
            expect.sort_unstable();
            assert_eq!(got, expect, "p={p}");
            // payloads follow their keys
            for (k, v) in out.iter().flat_map(|(k, v)| k.iter().zip(v.iter())) {
                assert_eq!(*v, *k * 2);
            }
        }
    }

    #[test]
    fn sorts_descending_and_multi_key() {
        let mut rng = Rng::new(23);
        let a: Vec<i64> = (0..80).map(|_| rng.i64_range(0, 5)).collect();
        let b: Vec<i64> = (0..80).map(|_| rng.i64_range(0, 100)).collect();
        for p in [1usize, 3] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(a.len(), p, c.rank());
                let ka = Column::I64(a[s..s + l].to_vec());
                let kb = Column::I64(b[s..s + l].to_vec());
                let (kcols, _) = distributed_sort_keys(
                    &c,
                    &[&ka, &kb],
                    &[SortOrder::Desc, SortOrder::Asc],
                    &[],
                )
                .unwrap();
                (kcols[0].as_i64().to_vec(), kcols[1].as_i64().to_vec())
            });
            let got: Vec<(i64, i64)> = out
                .iter()
                .flat_map(|(x, y)| x.iter().zip(y.iter()).map(|(&x, &y)| (x, y)))
                .collect();
            let mut expect: Vec<(i64, i64)> = a.iter().zip(&b).map(|(&x, &y)| (x, y)).collect();
            expect.sort_by(|u, v| v.0.cmp(&u.0).then(u.1.cmp(&v.1)));
            assert_eq!(got, expect, "p={p}");
        }
    }

    #[test]
    fn sorts_string_keys() {
        let words = ["pear", "apple", "fig", "apple", "date", "kiwi"];
        let out = run_spmd(2, |c| {
            let (s, l) = block_range(words.len(), 2, c.rank());
            let kc = Column::Str(words[s..s + l].iter().map(|w| w.to_string()).collect());
            let (kcols, _) =
                distributed_sort_keys(&c, &[&kc], &[SortOrder::Asc], &[]).unwrap();
            kcols[0].as_str_col().to_vec()
        });
        let got: Vec<String> = out.into_iter().flatten().collect();
        let mut expect: Vec<String> = words.iter().map(|w| w.to_string()).collect();
        expect.sort();
        assert_eq!(got, expect);
    }

    #[test]
    fn packed_sort_bool_key_and_directions() {
        // (bool, i64) keys with Desc bool: all `true` rows first, then by id
        let flags: Vec<bool> = (0..30).map(|i| i % 3 == 0).collect();
        let ids: Vec<i64> = (0..30).map(|i| (37 * i) % 30).collect();
        let out = run_spmd(3, |c| {
            let (s, l) = block_range(flags.len(), 3, c.rank());
            let kf = Column::Bool(flags[s..s + l].to_vec());
            let ki = Column::I64(ids[s..s + l].to_vec());
            let (kcols, _) = distributed_sort_keys(
                &c,
                &[&kf, &ki],
                &[SortOrder::Desc, SortOrder::Asc],
                &[],
            )
            .unwrap();
            (kcols[0].as_bool().to_vec(), kcols[1].as_i64().to_vec())
        });
        let got: Vec<(bool, i64)> = out
            .iter()
            .flat_map(|(f, i)| f.iter().zip(i.iter()).map(|(&f, &i)| (f, i)))
            .collect();
        let mut expect: Vec<(bool, i64)> =
            flags.iter().zip(&ids).map(|(&f, &i)| (f, i)).collect();
        expect.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        assert_eq!(got, expect);
    }

    #[test]
    fn packed_sort_extreme_i64_values() {
        let data = vec![0i64, i64::MAX, i64::MIN, -1, 1, i64::MIN, i64::MAX];
        let out = run_spmd(2, |c| {
            let (s, l) = block_range(data.len(), 2, c.rank());
            let (k, _) = distributed_sort_by_key(&c, &data[s..s + l], &[]).unwrap();
            k
        });
        let got: Vec<i64> = out.into_iter().flatten().collect();
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn sorts_with_duplicates_and_empty_ranks() {
        let data = vec![5i64, 5, 5, 5, 5, 5];
        let out = run_spmd(4, |c| {
            let (s, l) = block_range(data.len(), 4, c.rank());
            let (k, _) = distributed_sort_by_key(&c, &data[s..s + l], &[]).unwrap();
            k
        });
        let got: Vec<i64> = out.into_iter().flatten().collect();
        assert_eq!(got, data);
    }

    #[test]
    fn empty_input() {
        let out = run_spmd(2, |c| {
            let (k, _) = distributed_sort_by_key(&c, &[], &[]).unwrap();
            k.len()
        });
        assert_eq!(out, vec![0, 0]);
    }
}
