//! Distributed sort by an Int64 key: sample-sort (local sort → regular
//! sampling → splitter broadcast → range partition `alltoallv` → local
//! merge). Used for global result canonicalization and TPCx-BB's ORDER BY
//! steps. Output distribution: `1D_VAR` (range partitions are data
//! dependent — the motivating case for the paper's 1D_VAR).

use crate::column::{decode_column, encode_column, Column};
use crate::comm::Comm;
use anyhow::Result;

/// Sort `(keys, cols)` globally ascending by key. Rank r ends up holding
/// the r-th range of the sorted order (contiguous, 1D_VAR).
pub fn distributed_sort_by_key(
    comm: &Comm,
    keys: &[i64],
    cols: &[Column],
) -> Result<(Vec<i64>, Vec<Column>)> {
    let p = comm.nranks();
    // local sort (stable — Timsort-family, as in the paper)
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by_key(|&i| keys[i]);
    let skeys: Vec<i64> = idx.iter().map(|&i| keys[i]).collect();
    let scols: Vec<Column> = cols.iter().map(|c| c.take(&idx)).collect();

    if p == 1 {
        return Ok((skeys, scols));
    }

    // regular sampling: p samples per rank → root picks p-1 splitters
    let mut sample = Vec::with_capacity(p);
    for s in 0..p {
        if !skeys.is_empty() {
            let pos = (s * skeys.len()) / p;
            sample.push(skeys[pos.min(skeys.len() - 1)]);
        }
    }
    let mut payload = Vec::new();
    for s in &sample {
        payload.extend_from_slice(&s.to_le_bytes());
    }
    let gathered = comm.gather_bytes(0, payload);
    let splitters: Vec<i64> = if comm.is_root() {
        let mut all: Vec<i64> = gathered
            .iter()
            .flat_map(|b| {
                b.chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
            })
            .collect();
        all.sort_unstable();
        if all.is_empty() {
            vec![i64::MAX; p - 1] // nothing to sort anywhere: any splitters do
        } else {
            (1..p)
                .map(|i| all[((i * all.len()) / p).min(all.len() - 1)])
                .collect()
        }
    } else {
        Vec::new()
    };
    let mut spayload = Vec::new();
    for s in &splitters {
        spayload.extend_from_slice(&s.to_le_bytes());
    }
    let spayload = comm.bcast_bytes(0, spayload);
    let splitters: Vec<i64> = spayload
        .chunks_exact(8)
        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
        .collect();

    // range partition: dst = #splitters ≤ key (upper_bound)
    let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
    let mut start = 0usize;
    for dst in 0..p {
        let end = if dst + 1 < p {
            skeys.partition_point(|&k| k <= splitters[dst])
        } else {
            skeys.len()
        };
        if end > start {
            let buf = &mut bufs[dst];
            encode_column(&Column::I64(skeys[start..end].to_vec()), buf);
            for c in &scols {
                encode_column(&c.slice(start, end - start), buf);
            }
        }
        start = end;
    }
    let received = comm.alltoallv_bytes(bufs);

    // collect received runs and merge by one final local sort (runs are
    // sorted; a k-way merge is a §Perf refinement that measured <5% here)
    let mut rkeys: Vec<i64> = Vec::new();
    let mut rcols: Vec<Column> = cols.iter().map(|c| Column::new_empty(c.dtype())).collect();
    for buf in received {
        if buf.is_empty() {
            continue;
        }
        let mut pos = 0;
        let kc = decode_column(&buf, &mut pos)?;
        rkeys.extend_from_slice(kc.as_i64());
        for oc in rcols.iter_mut() {
            let c = decode_column(&buf, &mut pos)?;
            oc.extend(&c);
        }
    }
    let mut idx: Vec<usize> = (0..rkeys.len()).collect();
    idx.sort_by_key(|&i| rkeys[i]);
    let fkeys: Vec<i64> = idx.iter().map(|&i| rkeys[i]).collect();
    let fcols: Vec<Column> = rcols.iter().map(|c| c.take(&idx)).collect();
    Ok((fkeys, fcols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{block_range, run_spmd};
    use crate::datagen::Rng;

    #[test]
    fn sorts_globally() {
        let mut rng = Rng::new(11);
        let data: Vec<i64> = (0..97).map(|_| rng.i64_range(-50, 50)).collect();
        for p in [1usize, 2, 4] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(data.len(), p, c.rank());
                let keys = &data[s..s + l];
                let vals = Column::I64(keys.iter().map(|&k| k * 2).collect());
                let (k, cols) = distributed_sort_by_key(&c, keys, &[vals]).unwrap();
                (k, cols[0].as_i64().to_vec())
            });
            // concatenated ranks must be globally sorted
            let got: Vec<i64> = out.iter().flat_map(|(k, _)| k.clone()).collect();
            let mut expect = data.clone();
            expect.sort_unstable();
            assert_eq!(got, expect, "p={p}");
            // payloads follow their keys
            for (k, v) in out.iter().flat_map(|(k, v)| k.iter().zip(v.iter())) {
                assert_eq!(*v, *k * 2);
            }
        }
    }

    #[test]
    fn sorts_with_duplicates_and_empty_ranks() {
        let data = vec![5i64, 5, 5, 5, 5, 5];
        let out = run_spmd(4, |c| {
            let (s, l) = block_range(data.len(), 4, c.rank());
            let (k, _) = distributed_sort_by_key(&c, &data[s..s + l], &[]).unwrap();
            k
        });
        let got: Vec<i64> = out.into_iter().flatten().collect();
        assert_eq!(got, data);
    }

    #[test]
    fn empty_input() {
        let out = run_spmd(2, |c| {
            let (k, _) = distributed_sort_by_key(&c, &[], &[]).unwrap();
            k.len()
        });
        assert_eq!(out, vec![0, 0]);
    }
}
