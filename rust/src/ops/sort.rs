//! Distributed sort over composite keys with per-key directions:
//! sample-sort (local sort → regular sampling → splitter broadcast → range
//! partition `alltoallv` → local merge). Used for global result
//! canonicalization and TPCx-BB's multi-column ORDER BY steps. Output
//! distribution: `1D_VAR` (range partitions are data dependent — the
//! motivating case for the paper's 1D_VAR).
//!
//! Int64/Bool key lists take the packed fast path ([`SortKeys`]):
//! direction-aware fixed-width byte rows where every comparison — local
//! sort, splitter selection, range partition — is a `memcmp`, and splitters
//! travel as raw packed rows. Key lists containing String columns fall back
//! to materialized [`KeyRow`] tuples shipped through the [`keys`] wire
//! codec, ordered by [`cmp_key_rows`].
//!
//! Null keys order as the smallest value (nulls *first* ascending, last
//! descending) in both paths: the packed layout's validity flag byte
//! precedes the value bytes, [`KeyVal::Null`] is the smallest `KeyVal`.
//! Because the flagged row width must match on every rank (splitters are
//! raw rows), the flag choice is agreed globally up front.
//!
//! Under a spill budget ([`super::spill::SpillCtx`]) the packed path's two
//! local sort phases switch to an external merge sort — contiguous sorted
//! runs on disk plus a streaming k-way merge — that reproduces the stable
//! in-memory order byte for byte (see [`external_merge_sort`]).

use super::join::MaskedCol;
use super::keys::{
    self, cmp_key_rows, decode_key_row, encode_key_row, KeyNullability, KeyRow, SortKeys,
};
use super::spill::{masked_bytes, FrameReader, SpillCtx, SPILL_CHUNK_ROWS};
use crate::column::{
    decode_nullable_column, encode_nullable_column, encode_nullable_column_take, extend_opt_mask,
    Column, NullableColumn, ValidityMask,
};
use crate::comm::Comm;
use crate::types::SortOrder;
use anyhow::{bail, Result};
use std::cmp::Ordering;

/// Sort `(key_cols, payload)` globally by the key tuples under `orders`
/// (one direction per key column); every column may carry a validity mask.
/// Rank r ends up holding the r-th range of the sorted order (contiguous,
/// 1D_VAR). Returns the sorted key columns (dtypes preserved, masks kept)
/// and payload columns.
pub fn distributed_sort_keys(
    comm: &Comm,
    key_cols: &[MaskedCol],
    orders: &[SortOrder],
    payload: &[MaskedCol],
    nullability: KeyNullability,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    distributed_sort_keys_budgeted(
        comm,
        key_cols,
        orders,
        payload,
        nullability,
        &SpillCtx::unlimited(),
    )
}

/// [`distributed_sort_keys`] under a spill budget: when a rank's working
/// set exceeds `spill`'s budget, the packed path's two local sort phases
/// fall back to an external merge sort (sorted runs on disk + streaming
/// k-way merge) instead of materializing the full argsorted copy. The
/// String-key KeyRow fallback stays in memory — out-of-core ordering is
/// defined over the fixed-width [`SortKeys`] layout. With an unlimited
/// budget every step is byte-identical to [`distributed_sort_keys`].
pub fn distributed_sort_keys_budgeted(
    comm: &Comm,
    key_cols: &[MaskedCol],
    orders: &[SortOrder],
    payload: &[MaskedCol],
    nullability: KeyNullability,
    spill: &SpillCtx,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    if key_cols.is_empty() {
        bail!("sort: key column list must be non-empty");
    }
    let kc: Vec<&Column> = key_cols.iter().map(|(c, _)| *c).collect();
    let km: Vec<Option<&ValidityMask>> = key_cols.iter().map(|(_, m)| *m).collect();
    // flagged-vs-plain packed width must be identical on every rank (the
    // splitters travel as raw rows of that width); statically typed plans
    // resolve the choice from the schema with no collective
    let with_flags = nullability.with_flags(comm, km.iter().any(|m| m.is_some()));
    if let Some(sk) = SortKeys::pack_nullable(&kc, &km, orders, with_flags)? {
        return sort_packed(comm, sk, key_cols, orders, payload, with_flags, spill);
    }
    let p = comm.nranks();
    let krows = keys::key_rows_nullable(&kc, &km)?;
    // local sort: dictionary-encode the tuples into fixed-width rows and
    // radix-argsort them (stable, byte-identical to a comparison sort of
    // the tuples under `orders`)
    let idx = SortKeys::from_key_rows(&krows, orders).argsort();
    let skrows: Vec<KeyRow> = idx.iter().map(|&i| krows[i].clone()).collect();
    let skey: Vec<NullableColumn> = take_masked(key_cols, &idx);
    let spay: Vec<NullableColumn> = take_masked(payload, &idx);

    if p == 1 {
        return Ok((skey, spay));
    }

    // regular sampling: p sample tuples per non-empty rank → root picks
    // p-1 splitter tuples
    let mut sample_buf = Vec::new();
    if !skrows.is_empty() {
        for s in 0..p {
            let pos = ((s * skrows.len()) / p).min(skrows.len() - 1);
            encode_key_row(&skrows[pos], &mut sample_buf);
        }
    }
    let gathered = comm.gather_bytes(0, sample_buf);
    let mut splitter_buf = Vec::new();
    if comm.is_root() {
        let mut all: Vec<KeyRow> = Vec::new();
        for buf in &gathered {
            let mut pos = 0;
            while pos < buf.len() {
                all.push(decode_key_row(key_cols.len(), buf, &mut pos)?);
            }
        }
        all.sort_by(|a, b| cmp_key_rows(a, b, orders));
        if !all.is_empty() {
            for i in 1..p {
                let pos = ((i * all.len()) / p).min(all.len() - 1);
                encode_key_row(&all[pos], &mut splitter_buf);
            }
        }
        // nothing to sort anywhere → broadcast zero splitters; every rank's
        // (empty) data trivially lands in bucket 0
    }
    let splitter_buf = comm.bcast_bytes(0, splitter_buf);
    let mut splitters: Vec<KeyRow> = Vec::new();
    {
        let mut pos = 0;
        while pos < splitter_buf.len() {
            splitters.push(decode_key_row(key_cols.len(), &splitter_buf, &mut pos)?);
        }
    }

    // range partition: dst = #splitters ≤ key (upper_bound under `orders`)
    let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
    let mut start = 0usize;
    for dst in 0..p {
        let end = if dst < splitters.len() {
            start
                + skrows[start..].partition_point(|k| {
                    cmp_key_rows(k, &splitters[dst], orders) != Ordering::Greater
                })
        } else {
            skrows.len()
        };
        if end > start {
            let buf = &mut bufs[dst];
            encode_run(&skey, start, end, buf);
            encode_run(&spay, start, end, buf);
        }
        start = end;
        if start >= skrows.len() {
            break;
        }
    }
    let received = comm.alltoallv_bytes(bufs);

    // collect received runs and merge by one final local sort (runs are
    // sorted; a k-way merge is a §Perf refinement that measured <5% here)
    let (rkeys, rpay) = decode_runs(&kc, payload, received)?;
    let rk_refs: Vec<&Column> = rkeys.iter().map(|c| &c.values).collect();
    let rk_masks: Vec<Option<&ValidityMask>> =
        rkeys.iter().map(|c| c.validity.as_ref()).collect();
    let rrows = keys::key_rows_nullable(&rk_refs, &rk_masks)?;
    let idx = SortKeys::from_key_rows(&rrows, orders).argsort();
    Ok((take_owned(&rkeys, &idx), take_owned(&rpay, &idx)))
}

/// Packed sample-sort (Int64/Bool keys): every ordering decision is a byte
/// comparison of fixed-width direction-aware rows, and splitters are shipped
/// as raw packed rows — no tuple materialization, no per-cell wire codec.
fn sort_packed(
    comm: &Comm,
    sk: SortKeys,
    key_cols: &[MaskedCol],
    orders: &[SortOrder],
    payload: &[MaskedCol],
    with_flags: bool,
    spill: &SpillCtx,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    let p = comm.nranks();
    let n = sk.len();
    let nk = key_cols.len();
    // local sort (stable — Timsort-family, as in the paper — when the
    // working set fits the budget; external merge sort otherwise)
    let all: Vec<MaskedCol> = key_cols.iter().chain(payload.iter()).copied().collect();
    let (mut sorted, ssk) = sort_rows_budgeted(&sk, &all, nk, orders, with_flags, p > 1, spill)?;
    let spay = sorted.split_off(nk);
    let skey = sorted;

    if p == 1 {
        return Ok((skey, spay));
    }
    let ssk = ssk.expect("sorted keys requested for the multi-rank path");
    let w = ssk.width();

    // regular sampling: p packed sample rows per non-empty rank → root
    // picks p-1 splitter rows (raw bytes; width is schema-determined and
    // the flag choice was agreed globally, so every rank slices the
    // broadcast identically)
    let mut sample_buf = Vec::new();
    if n > 0 {
        for s in 0..p {
            let pos = ((s * n) / p).min(n - 1);
            sample_buf.extend_from_slice(ssk.row(pos));
        }
    }
    let gathered = comm.gather_bytes(0, sample_buf);
    let mut splitter_buf = Vec::new();
    if comm.is_root() {
        let mut all: Vec<&[u8]> = Vec::new();
        for buf in &gathered {
            for chunk in buf.chunks_exact(w) {
                all.push(chunk);
            }
        }
        all.sort();
        if !all.is_empty() {
            for i in 1..p {
                let pos = ((i * all.len()) / p).min(all.len() - 1);
                splitter_buf.extend_from_slice(all[pos]);
            }
        }
        // nothing to sort anywhere → broadcast zero splitters; every rank's
        // (empty) data trivially lands in bucket 0
    }
    let splitter_buf = comm.bcast_bytes(0, splitter_buf);
    let splitters: Vec<&[u8]> = splitter_buf.chunks_exact(w).collect();

    // range partition: dst = #splitters ≤ row (upper_bound via memcmp)
    let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
    let mut start = 0usize;
    for dst in 0..p {
        let end = if dst < splitters.len() {
            start + ssk.partition_le(start, splitters[dst])
        } else {
            n
        };
        if end > start {
            let buf = &mut bufs[dst];
            encode_run(&skey, start, end, buf);
            encode_run(&spay, start, end, buf);
        }
        start = end;
        if start >= n {
            break;
        }
    }
    let received = comm.alltoallv_bytes(bufs);

    // collect received runs and merge by one final packed local sort —
    // again in memory or external, depending on the budget
    let kc: Vec<&Column> = key_cols.iter().map(|(c, _)| *c).collect();
    let (rkeys, rpay) = decode_runs(&kc, payload, received)?;
    let rk_refs: Vec<&Column> = rkeys.iter().map(|c| &c.values).collect();
    let rk_masks: Vec<Option<&ValidityMask>> =
        rkeys.iter().map(|c| c.validity.as_ref()).collect();
    let rsk = SortKeys::pack_nullable(&rk_refs, &rk_masks, orders, with_flags)?
        .expect("Int64/Bool keys stay packable");
    let rall: Vec<MaskedCol> = rkeys
        .iter()
        .chain(rpay.iter())
        .map(|c| c.as_masked())
        .collect();
    let (mut rsorted, _) = sort_rows_budgeted(&rsk, &rall, nk, orders, with_flags, false, spill)?;
    let rp = rsorted.split_off(nk);
    Ok((rsorted, rp))
}

/// Stable sort of `cols`' rows by `sk`'s packed bytes: the plain in-memory
/// argsort + gather when the working set fits the budget, the external
/// merge sort otherwise. The `nk` leading columns are the sort keys (the
/// external path re-packs them chunk-at-a-time while merging). With
/// `need_keys` the packed keys of the sorted order are returned too — on
/// the external path they are re-packed from the sorted key columns, which
/// is byte-identical to `sk.take(&idx)` because packing is a pure row-wise
/// function of (values, validity, orders, with_flags): invalid lanes pack
/// as flag 0 + value 0 whatever they store, and a mask normalized away
/// packs like an all-valid mask.
fn sort_rows_budgeted(
    sk: &SortKeys,
    cols: &[MaskedCol],
    nk: usize,
    orders: &[SortOrder],
    with_flags: bool,
    need_keys: bool,
    spill: &SpillCtx,
) -> Result<(Vec<NullableColumn>, Option<SortKeys>)> {
    if !spill.should_spill(masked_bytes(cols)) {
        let idx = sk.argsort();
        let keys = if need_keys { Some(sk.take(&idx)) } else { None };
        return Ok((take_masked(cols, &idx), keys));
    }
    let sorted = external_merge_sort(sk, cols, nk, orders, with_flags, spill)?;
    let keys = if need_keys {
        let krefs: Vec<&Column> = sorted[..nk].iter().map(|c| &c.values).collect();
        let kmasks: Vec<Option<&ValidityMask>> =
            sorted[..nk].iter().map(|c| c.validity.as_ref()).collect();
        Some(
            SortKeys::pack_nullable(&krefs, &kmasks, orders, with_flags)?
                .expect("Int64/Bool keys stay packable"),
        )
    } else {
        None
    };
    Ok((sorted, keys))
}

/// External merge sort of `cols` by `sk`: contiguous run slices sized to
/// the budget are stable-sorted in memory, spilled in sorted order, and
/// streamed back through a k-way merge that pops the smallest current head
/// row, breaking key ties toward the earlier run.
///
/// Byte-identity with the in-memory stable argsort: the runs partition the
/// original row order into *contiguous* slices, so among tied head rows
/// "earlier run" is exactly "earlier original position", and each run is
/// itself stably sorted — by induction the merged output is the global
/// stable sort. Values (null-lane fillers included) and validity bits
/// roundtrip bit-exactly through the nullable codec, and each run reader
/// holds only one decoded chunk ([`SPILL_CHUNK_ROWS`] rows), so peak
/// memory is O(runs × chunk) instead of O(n).
fn external_merge_sort(
    sk: &SortKeys,
    cols: &[MaskedCol],
    nk: usize,
    orders: &[SortOrder],
    with_flags: bool,
    spill: &SpillCtx,
) -> Result<Vec<NullableColumn>> {
    let n = sk.len();
    let nruns = spill.budget().partition_count(masked_bytes(cols));
    let run_rows = n.div_ceil(nruns).max(1);

    let mut files = Vec::with_capacity(nruns);
    let mut spilled_bytes = 0u64;
    let mut frame = Vec::new();
    let mut start = 0usize;
    while start < n {
        let end = (start + run_rows).min(n);
        let idx = sk.argsort_range(start, end);
        let mut file = spill.new_file("sort-run")?;
        for chunk in idx.chunks(SPILL_CHUNK_ROWS) {
            frame.clear();
            for &(c, m) in cols {
                encode_nullable_column_take(c, m, chunk, &mut frame);
            }
            file.write_frame(chunk.len(), &frame)?;
        }
        file.finish()?;
        spilled_bytes += file.bytes();
        files.push(file);
        start = end;
    }
    spill.record_spill_pass(files.len() as u64, spilled_bytes);

    let mut cursors = Vec::with_capacity(files.len());
    for file in &mut files {
        let mut cur = RunCursor {
            reader: file.reader()?,
            cols: Vec::new(),
            masks: Vec::new(),
            keys: None,
            pos: 0,
        };
        cur.refill(cols.len(), nk, orders, with_flags)?;
        cursors.push(cur);
    }
    spill.record_merge_pass();

    let mut out: Vec<(Column, ValidityMask)> = cols
        .iter()
        .map(|&(c, _)| (Column::new_empty(c.dtype()), ValidityMask::new_valid(0)))
        .collect();
    loop {
        let mut best: Option<usize> = None;
        for r in 0..cursors.len() {
            if cursors[r].exhausted() {
                continue;
            }
            best = Some(match best {
                // strict "smaller wins" keeps key ties on the earlier run
                Some(b) if cursors[r].key() >= cursors[b].key() => b,
                _ => r,
            });
        }
        let Some(b) = best else { break };
        let cur = &cursors[b];
        for ((oc, om), (c, m)) in out.iter_mut().zip(cur.cols.iter().zip(&cur.masks)) {
            oc.push(&c.get(cur.pos));
            om.push(m.as_ref().map_or(true, |m| m.get(cur.pos)));
        }
        cursors[b].advance(cols.len(), nk, orders, with_flags)?;
    }
    Ok(out
        .into_iter()
        .map(|(c, m)| NullableColumn::new(c, Some(m)))
        .collect())
}

/// One run's streaming state in the k-way merge: the current decoded chunk
/// plus that chunk's rows re-packed under the same (orders, with_flags) as
/// the global [`SortKeys`] — packing is row-wise, so a chunk-local packed
/// row equals the global packing of the same row.
struct RunCursor {
    reader: FrameReader,
    cols: Vec<Column>,
    masks: Vec<Option<ValidityMask>>,
    keys: Option<SortKeys>,
    pos: usize,
}

impl RunCursor {
    fn exhausted(&self) -> bool {
        self.keys.as_ref().map_or(true, |k| self.pos >= k.len())
    }

    fn key(&self) -> &[u8] {
        self.keys
            .as_ref()
            .expect("cursor checked non-exhausted")
            .row(self.pos)
    }

    fn refill(
        &mut self,
        ncols: usize,
        nk: usize,
        orders: &[SortOrder],
        with_flags: bool,
    ) -> Result<()> {
        self.pos = 0;
        self.keys = None;
        let Some(frame) = self.reader.next_frame()? else {
            return Ok(());
        };
        let mut at = 0usize;
        self.cols.clear();
        self.masks.clear();
        for _ in 0..ncols {
            let (c, m) = decode_nullable_column(&frame, &mut at)?;
            self.cols.push(c);
            self.masks.push(m);
        }
        let krefs: Vec<&Column> = self.cols[..nk].iter().collect();
        let kmasks: Vec<Option<&ValidityMask>> =
            self.masks[..nk].iter().map(|m| m.as_ref()).collect();
        self.keys = Some(
            SortKeys::pack_nullable(&krefs, &kmasks, orders, with_flags)?
                .expect("Int64/Bool keys stay packable"),
        );
        Ok(())
    }

    fn advance(
        &mut self,
        ncols: usize,
        nk: usize,
        orders: &[SortOrder],
        with_flags: bool,
    ) -> Result<()> {
        self.pos += 1;
        if self.exhausted() {
            self.refill(ncols, nk, orders, with_flags)?;
        }
        Ok(())
    }
}

fn take_masked(cols: &[MaskedCol], idx: &[usize]) -> Vec<NullableColumn> {
    cols.iter()
        .map(|(c, m)| NullableColumn::new(c.take(idx), m.map(|m| m.take(idx))))
        .collect()
}

fn take_owned(cols: &[NullableColumn], idx: &[usize]) -> Vec<NullableColumn> {
    cols.iter()
        .map(|c| {
            NullableColumn::new(
                c.values.take(idx),
                c.validity.as_ref().map(|m| m.take(idx)),
            )
        })
        .collect()
}

fn encode_run(cols: &[NullableColumn], start: usize, end: usize, buf: &mut Vec<u8>) {
    for c in cols {
        encode_nullable_column(
            &c.values.slice(start, end - start),
            c.validity
                .as_ref()
                .map(|m| m.slice(start, end - start))
                .as_ref(),
            buf,
        );
    }
}

fn decode_runs(
    key_templates: &[&Column],
    payload: &[MaskedCol],
    received: Vec<Vec<u8>>,
) -> Result<(Vec<NullableColumn>, Vec<NullableColumn>)> {
    let mut rkeys: Vec<(Column, Option<ValidityMask>)> = key_templates
        .iter()
        .map(|c| (Column::new_empty(c.dtype()), None))
        .collect();
    let mut rpay: Vec<(Column, Option<ValidityMask>)> = payload
        .iter()
        .map(|(c, _)| (Column::new_empty(c.dtype()), None))
        .collect();
    for buf in received {
        if buf.is_empty() {
            continue;
        }
        let mut pos = 0;
        for (oc, om) in rkeys.iter_mut().chain(rpay.iter_mut()) {
            let before = oc.len();
            let (c, m) = decode_nullable_column(&buf, &mut pos)?;
            oc.extend(&c);
            extend_opt_mask(om, before, m.as_ref(), c.len());
        }
    }
    let wrap = |v: Vec<(Column, Option<ValidityMask>)>| {
        v.into_iter()
            .map(|(c, m)| NullableColumn::new(c, m))
            .collect()
    };
    Ok((wrap(rkeys), wrap(rpay)))
}

/// Sort `(keys, cols)` globally ascending by a single i64 key — the seed
/// API, kept as a wrapper over [`distributed_sort_keys`].
pub fn distributed_sort_by_key(
    comm: &Comm,
    keys: &[i64],
    cols: &[Column],
) -> Result<(Vec<i64>, Vec<Column>)> {
    let kc = Column::I64(keys.to_vec());
    let crefs: Vec<MaskedCol> = cols.iter().map(|c| (c, None)).collect();
    // a caller-built plain i64 key is non-nullable by construction
    let (kcols, pay) = distributed_sort_keys(
        comm,
        &[(&kc, None)],
        &[SortOrder::Asc],
        &crefs,
        KeyNullability::Static(false),
    )?;
    Ok((
        kcols[0].values.as_i64().to_vec(),
        pay.into_iter().map(|c| c.values).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{block_range, run_spmd};
    use crate::datagen::Rng;
    use crate::metrics::spill_stats;

    #[test]
    fn sorts_globally() {
        let mut rng = Rng::new(11);
        let data: Vec<i64> = (0..97).map(|_| rng.i64_range(-50, 50)).collect();
        for p in [1usize, 2, 4] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(data.len(), p, c.rank());
                let keys = &data[s..s + l];
                let vals = Column::I64(keys.iter().map(|&k| k * 2).collect());
                let (k, cols) = distributed_sort_by_key(&c, keys, &[vals]).unwrap();
                (k, cols[0].as_i64().to_vec())
            });
            // concatenated ranks must be globally sorted
            let got: Vec<i64> = out.iter().flat_map(|(k, _)| k.clone()).collect();
            let mut expect = data.clone();
            expect.sort_unstable();
            assert_eq!(got, expect, "p={p}");
            // payloads follow their keys
            for (k, v) in out.iter().flat_map(|(k, v)| k.iter().zip(v.iter())) {
                assert_eq!(*v, *k * 2);
            }
        }
    }

    #[test]
    fn sorts_descending_and_multi_key() {
        let mut rng = Rng::new(23);
        let a: Vec<i64> = (0..80).map(|_| rng.i64_range(0, 5)).collect();
        let b: Vec<i64> = (0..80).map(|_| rng.i64_range(0, 100)).collect();
        for p in [1usize, 3] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(a.len(), p, c.rank());
                let ka = Column::I64(a[s..s + l].to_vec());
                let kb = Column::I64(b[s..s + l].to_vec());
                let (kcols, _) = distributed_sort_keys(
                    &c,
                    &[(&ka, None), (&kb, None)],
                    &[SortOrder::Desc, SortOrder::Asc],
                    &[],
                    KeyNullability::Runtime,
                )
                .unwrap();
                (
                    kcols[0].values.as_i64().to_vec(),
                    kcols[1].values.as_i64().to_vec(),
                )
            });
            let got: Vec<(i64, i64)> = out
                .iter()
                .flat_map(|(x, y)| x.iter().zip(y.iter()).map(|(&x, &y)| (x, y)))
                .collect();
            let mut expect: Vec<(i64, i64)> = a.iter().zip(&b).map(|(&x, &y)| (x, y)).collect();
            expect.sort_by(|u, v| v.0.cmp(&u.0).then(u.1.cmp(&v.1)));
            assert_eq!(got, expect, "p={p}");
        }
    }

    #[test]
    fn sorts_string_keys() {
        let words = ["pear", "apple", "fig", "apple", "date", "kiwi"];
        let out = run_spmd(2, |c| {
            let (s, l) = block_range(words.len(), 2, c.rank());
            let kc = Column::Str(words[s..s + l].iter().map(|w| w.to_string()).collect());
            let (kcols, _) = distributed_sort_keys(
                &c,
                &[(&kc, None)],
                &[SortOrder::Asc],
                &[],
                KeyNullability::Runtime,
            )
            .unwrap();
            kcols[0].values.as_str_col().to_vec()
        });
        let got: Vec<String> = out.into_iter().flatten().collect();
        let mut expect: Vec<String> = words.iter().map(|w| w.to_string()).collect();
        expect.sort();
        assert_eq!(got, expect);
    }

    #[test]
    fn packed_sort_bool_key_and_directions() {
        // (bool, i64) keys with Desc bool: all `true` rows first, then by id
        let flags: Vec<bool> = (0..30).map(|i| i % 3 == 0).collect();
        let ids: Vec<i64> = (0..30).map(|i| (37 * i) % 30).collect();
        let out = run_spmd(3, |c| {
            let (s, l) = block_range(flags.len(), 3, c.rank());
            let kf = Column::Bool(flags[s..s + l].to_vec());
            let ki = Column::I64(ids[s..s + l].to_vec());
            let (kcols, _) = distributed_sort_keys(
                &c,
                &[(&kf, None), (&ki, None)],
                &[SortOrder::Desc, SortOrder::Asc],
                &[],
                KeyNullability::Runtime,
            )
            .unwrap();
            (
                kcols[0].values.as_bool().to_vec(),
                kcols[1].values.as_i64().to_vec(),
            )
        });
        let got: Vec<(bool, i64)> = out
            .iter()
            .flat_map(|(f, i)| f.iter().zip(i.iter()).map(|(&f, &i)| (f, i)))
            .collect();
        let mut expect: Vec<(bool, i64)> =
            flags.iter().zip(&ids).map(|(&f, &i)| (f, i)).collect();
        expect.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        assert_eq!(got, expect);
    }

    #[test]
    fn packed_sort_extreme_i64_values() {
        let data = vec![0i64, i64::MAX, i64::MIN, -1, 1, i64::MIN, i64::MAX];
        let out = run_spmd(2, |c| {
            let (s, l) = block_range(data.len(), 2, c.rank());
            let (k, _) = distributed_sort_by_key(&c, &data[s..s + l], &[]).unwrap();
            k
        });
        let got: Vec<i64> = out.into_iter().flatten().collect();
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn nullable_keys_sort_nulls_first_across_ranks() {
        // values 0..24 with every multiple of 5 null (scrubbed to 0); only
        // some ranks hold masks, exercising the global flag agreement
        let data: Vec<i64> = (0..24).map(|i| if i % 5 == 0 { 0 } else { i }).collect();
        let nulls: Vec<bool> = (0..24).map(|i| i % 5 == 0).collect();
        for p in [2usize, 3] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(data.len(), p, c.rank());
                let kc = Column::I64(data[s..s + l].to_vec());
                let local_nulls = &nulls[s..s + l];
                let mask = if local_nulls.iter().any(|&b| b) {
                    Some(ValidityMask::from_bools(
                        &local_nulls.iter().map(|&b| !b).collect::<Vec<_>>(),
                    ))
                } else {
                    None
                };
                let pay = Column::I64(data[s..s + l].iter().map(|&v| v * 3).collect());
                let (kcols, pcols) = distributed_sort_keys(
                    &c,
                    &[(&kc, mask.as_ref())],
                    &[SortOrder::Asc],
                    &[(&pay, None)],
                    KeyNullability::Runtime,
                )
                .unwrap();
                let valid: Vec<bool> =
                    (0..kcols[0].len()).map(|i| kcols[0].is_valid(i)).collect();
                (
                    kcols[0].values.as_i64().to_vec(),
                    valid,
                    pcols[0].values.as_i64().to_vec(),
                )
            });
            let rows: Vec<(bool, i64, i64)> = out
                .iter()
                .flat_map(|(k, v, pl)| {
                    k.iter()
                        .zip(v.iter())
                        .zip(pl.iter())
                        .map(|((&k, &v), &pl)| (v, k, pl))
                })
                .collect();
            // all nulls first, then ascending values; payload attached
            let n_null = nulls.iter().filter(|&&b| b).count();
            assert_eq!(rows.len(), 24, "p={p}");
            for (i, (valid, k, _)) in rows.iter().enumerate() {
                assert_eq!(*valid, i >= n_null, "p={p} row {i}");
                if !*valid {
                    assert_eq!(*k, 0, "null lanes hold the dtype default");
                }
            }
            let valid_keys: Vec<i64> =
                rows.iter().filter(|(v, _, _)| *v).map(|(_, k, _)| *k).collect();
            let mut expect: Vec<i64> = (0..24).filter(|i| i % 5 != 0).collect();
            expect.sort_unstable();
            assert_eq!(valid_keys, expect, "p={p}");
            for (v, k, pl) in &rows {
                if *v {
                    assert_eq!(*pl, k * 3);
                }
            }
        }
    }

    #[test]
    fn static_nullability_skips_the_layout_allgather() {
        // a statically non-nullable key set resolves the packed layout from
        // the schema: same order, one collective fewer than the runtime gate
        let data: Vec<i64> = (0..30).map(|i| (i * 17) % 13).collect();
        let run = |nullability: KeyNullability| {
            crate::comm::run_spmd_with_stats(3, |c| {
                let (s, l) = block_range(data.len(), 3, c.rank());
                let kc = Column::I64(data[s..s + l].to_vec());
                let (kcols, _) = distributed_sort_keys(
                    &c,
                    &[(&kc, None)],
                    &[SortOrder::Asc],
                    &[],
                    nullability,
                )
                .unwrap();
                kcols[0].values.as_i64().to_vec()
            })
        };
        let (a, stats_static) = run(KeyNullability::Static(false));
        let (b, stats_runtime) = run(KeyNullability::Runtime);
        assert_eq!(a, b);
        assert!(
            stats_static.snapshot().3 < stats_runtime.snapshot().3,
            "static gate must skip the layout allgather"
        );
        // Static(true) forces the flagged layout with no collective either,
        // and stays order-identical for fully valid keys
        let (c_, _) = run(KeyNullability::Static(true));
        assert_eq!(a, c_);
    }

    #[test]
    fn budgeted_sort_is_byte_identical_and_spills() {
        use super::super::spill::{MemoryBudget, SpillCtx};
        // duplicate-heavy keys + a row-id payload make any stability
        // violation or row reorder visible; nulls exercise the flagged
        // layout through the spill codec roundtrip
        let mut rng = Rng::new(41);
        let data: Vec<i64> = (0..240).map(|_| rng.i64_range(0, 8)).collect();
        let nulls: Vec<bool> = (0..240).map(|i| i % 7 == 0).collect();
        let run = |budget: Option<usize>| {
            run_spmd(3, |c| {
                let (s, l) = block_range(data.len(), 3, c.rank());
                let kc = Column::I64(data[s..s + l].to_vec());
                let mask = ValidityMask::from_bools(
                    &nulls[s..s + l].iter().map(|&b| !b).collect::<Vec<_>>(),
                );
                let pay = Column::I64((s as i64..(s + l) as i64).collect());
                let spill = SpillCtx::new(MemoryBudget::from_opt(budget), c.rank());
                let (kcols, pcols) = distributed_sort_keys_budgeted(
                    &c,
                    &[(&kc, Some(&mask))],
                    &[SortOrder::Asc],
                    &[(&pay, None)],
                    KeyNullability::Runtime,
                    &spill,
                )
                .unwrap();
                (0..kcols[0].len())
                    .map(|i| {
                        (
                            kcols[0].values.as_i64()[i],
                            kcols[0].is_valid(i),
                            pcols[0].values.as_i64()[i],
                        )
                    })
                    .collect::<Vec<_>>()
            })
        };
        let base = run(None);
        let before = spill_stats().snapshot();
        let tight = run(Some(256)); // ~2KB per rank >> 256B: both phases spill
        let after = spill_stats().snapshot();
        assert_eq!(base, tight, "budgeted sort diverged from in-memory sort");
        // counters are global, so only the delta around the tight run is
        // ours to assert on (and concurrent tests can only add to it)
        assert!(after.bytes_spilled > before.bytes_spilled);
        assert!(after.spill_passes > before.spill_passes);
        assert!(after.merge_passes > before.merge_passes);
    }

    #[test]
    fn sorts_with_duplicates_and_empty_ranks() {
        let data = vec![5i64, 5, 5, 5, 5, 5];
        let out = run_spmd(4, |c| {
            let (s, l) = block_range(data.len(), 4, c.rank());
            let (k, _) = distributed_sort_by_key(&c, &data[s..s + l], &[]).unwrap();
            k
        });
        let got: Vec<i64> = out.into_iter().flatten().collect();
        assert_eq!(got, data);
    }

    #[test]
    fn empty_input() {
        let out = run_spmd(2, |c| {
            let (k, _) = distributed_sort_by_key(&c, &[], &[]).unwrap();
            k.len()
        });
        assert_eq!(out, vec![0, 0]);
    }
}
