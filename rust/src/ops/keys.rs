//! Composite-key machinery shared by the distributed join / aggregate /
//! sort operators and the baseline engines.
//!
//! A relational key in the redesigned API is a *list* of columns
//! (`on: &[("lk","rk")]`, `aggregate(&["k1","k2"], …)`). Two runtime
//! representations coexist:
//!
//! * [`KeyVal`] / [`KeyRow`] — one boxed tuple per row. This is the
//!   API/typing boundary representation, the wire format for splitters and
//!   pre-aggregation records, and what the serial/sparklike baseline engines
//!   use (keeping the engine-agreement tests a true cross-check).
//! * [`PackedKeys`] — the HiFrames fast path: a columnar, allocation-free
//!   encoding of the whole key column set at once. A single Int64 key is a
//!   zero-copy borrow of the column; multi-column Int64/Bool keys byte-pack
//!   into fixed-width order-preserving rows; a single String key column is
//!   dictionary-encoded (one escaped entry per distinct string, `u32` codes
//!   per row, per-entry hashes computed once); other keys containing String
//!   columns fall back to variable-width order-preserving rows with a
//!   per-operator string interner. Hashing (routing rows to their owner
//!   rank — the
//!   composite generalization of the paper's `_df_id[i] % npes`), equality
//!   and ascending tuple order are all answered without materializing a
//!   single `Vec<KeyVal>`.
//!
//! Float64 columns are rejected as keys at plan-typing time, so every key
//! cell has exact equality.

use crate::column::{Column, NullableColumn, ValidityMask};
use crate::comm::Comm;
use crate::fxhash::{self, FxHashMap, FxHasher};
use crate::types::{DType, SortOrder, Value};
use anyhow::{bail, Result};
use std::cmp::Ordering;
use std::hash::{BuildHasher, BuildHasherDefault};

/// Does any rank contribute `local` = true? Layout decisions that feed the
/// hash-routing (flagged vs. unflagged packed keys) must be *globally*
/// consistent, or equal keys would land on different owner ranks.
pub(crate) fn global_any(comm: &Comm, local: bool) -> bool {
    comm.allgather_bytes(vec![local as u8])
        .iter()
        .any(|b| b.first().copied().unwrap_or(0) != 0)
}

/// How an operator learns whether its key columns can carry nulls — the
/// input to the flagged-vs-plain packed-layout choice, which must be
/// identical on every rank (owner hashing is a function of the packed
/// bytes).
///
/// The schema's *static* nullable flags are replicated knowledge: every
/// rank compiled the same plan, so when the caller knows them the layout
/// can be chosen with **no collective at all**. Only schema-less callers
/// (ops-level tests, ad-hoc kernels) need the runtime allgather.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyNullability {
    /// The plan schema says whether any key column is nullable — a global
    /// fact; `Static(false)` skips the allgather *and* keeps the plain
    /// layout (canonical form guarantees no runtime mask exists then).
    Static(bool),
    /// Unknown statically: agree at run time with one allgather.
    Runtime,
}

impl KeyNullability {
    /// Resolve the flagged-layout choice. `local_has_mask` is whether this
    /// rank's key columns actually carry a validity mask.
    pub fn with_flags(self, comm: &Comm, local_has_mask: bool) -> bool {
        match self {
            KeyNullability::Static(nullable) => {
                debug_assert!(
                    nullable || !local_has_mask,
                    "validity mask present on statically non-nullable key columns"
                );
                nullable
            }
            KeyNullability::Runtime => global_any(comm, local_has_mask),
        }
    }
}

/// One cell of a composite key. Variants cover exactly the groupable dtypes
/// plus the null cell. `Null` is declared *first* so the derived `Ord`
/// places nulls before every value — the nulls-first rule every key path
/// (KeyRow and packed) shares. Null keys equal each other (a null group /
/// null-key join matches, the Pandas rule rather than SQL's).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KeyVal {
    Null,
    I64(i64),
    Bool(bool),
    Str(String),
}

impl KeyVal {
    /// Convert from a row-engine [`Value`] cell (F64 keys are rejected).
    pub fn from_value(v: &Value) -> Result<KeyVal> {
        Ok(match v {
            Value::I64(x) => KeyVal::I64(*x),
            Value::Bool(x) => KeyVal::Bool(*x),
            Value::Str(x) => KeyVal::Str(x.clone()),
            Value::Null(dt) if dt.is_groupable() => KeyVal::Null,
            Value::F64(_) | Value::Null(_) => bail!("Float64 cannot be a relational key"),
        })
    }

    pub fn to_value(&self) -> Value {
        match self {
            KeyVal::I64(x) => Value::I64(*x),
            KeyVal::Bool(x) => Value::Bool(*x),
            KeyVal::Str(x) => Value::Str(x.clone()),
            KeyVal::Null => panic!("KeyVal::Null needs a dtype — use to_value_typed"),
        }
    }

    /// [`KeyVal::to_value`] with the column dtype supplied, so null cells
    /// can round-trip as typed [`Value::Null`]s.
    pub fn to_value_typed(&self, dt: DType) -> Value {
        match self {
            KeyVal::Null => Value::Null(dt),
            other => other.to_value(),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, KeyVal::Null)
    }
}

/// A full key tuple for one row.
pub type KeyRow = Vec<KeyVal>;

/// Materialize per-row key tuples from the key columns (all equal length).
pub fn key_rows(cols: &[&Column]) -> Result<Vec<KeyRow>> {
    let masks: Vec<Option<&ValidityMask>> = vec![None; cols.len()];
    key_rows_nullable(cols, &masks)
}

/// Materialize per-row key tuples from nullable key columns: invalid rows
/// become [`KeyVal::Null`] cells.
pub fn key_rows_nullable(
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
) -> Result<Vec<KeyRow>> {
    debug_assert_eq!(cols.len(), masks.len());
    let n = cols.first().map_or(0, |c| c.len());
    let mut out: Vec<KeyRow> = (0..n).map(|_| Vec::with_capacity(cols.len())).collect();
    for (c, mask) in cols.iter().zip(masks) {
        let valid = |i: usize| mask.map_or(true, |m| m.get(i));
        match c {
            Column::I64(v) => {
                for (i, (row, x)) in out.iter_mut().zip(v).enumerate() {
                    row.push(if valid(i) { KeyVal::I64(*x) } else { KeyVal::Null });
                }
            }
            Column::Bool(v) => {
                for (i, (row, x)) in out.iter_mut().zip(v).enumerate() {
                    row.push(if valid(i) { KeyVal::Bool(*x) } else { KeyVal::Null });
                }
            }
            Column::Str(v) => {
                for (i, (row, x)) in out.iter_mut().zip(v).enumerate() {
                    row.push(if valid(i) {
                        KeyVal::Str(x.clone())
                    } else {
                        KeyVal::Null
                    });
                }
            }
            Column::F64(_) => bail!("Float64 cannot be a relational key"),
        }
    }
    Ok(out)
}

/// Fx hash of one key tuple — the composite-key owner function input.
pub fn hash_key_row(row: &[KeyVal]) -> u64 {
    let b: BuildHasherDefault<FxHasher> = Default::default();
    b.hash_one(row)
}

/// Destination rank of a key tuple.
pub fn owner_of_key(row: &[KeyVal], nranks: usize) -> usize {
    (hash_key_row(row) % nranks as u64) as usize
}

/// Compare two key tuples under per-column sort directions. Missing
/// directions default to ascending (group-by canonical order).
pub fn cmp_key_rows(a: &[KeyVal], b: &[KeyVal], orders: &[SortOrder]) -> Ordering {
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let ord = x.cmp(y);
        let ord = match orders.get(i).copied().unwrap_or(SortOrder::Asc) {
            SortOrder::Asc => ord,
            SortOrder::Desc => ord.reverse(),
        };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

/// Wire-encode one key tuple (tag byte + payload per cell; tag 3 = null,
/// no payload).
pub fn encode_key_row(row: &[KeyVal], buf: &mut Vec<u8>) {
    for v in row {
        match v {
            KeyVal::I64(x) => {
                buf.push(0);
                buf.extend_from_slice(&x.to_le_bytes());
            }
            KeyVal::Bool(x) => {
                buf.push(1);
                buf.push(*x as u8);
            }
            KeyVal::Str(x) => {
                buf.push(2);
                buf.extend_from_slice(&(x.len() as u32).to_le_bytes());
                buf.extend_from_slice(x.as_bytes());
            }
            KeyVal::Null => buf.push(3),
        }
    }
}

/// Decode an `ncols`-cell key tuple written by [`encode_key_row`].
pub fn decode_key_row(ncols: usize, buf: &[u8], pos: &mut usize) -> Result<KeyRow> {
    let need = |pos: &usize, n: usize| -> Result<()> {
        if *pos + n > buf.len() {
            bail!("key row decode: truncated buffer");
        }
        Ok(())
    };
    let mut row = Vec::with_capacity(ncols);
    for _ in 0..ncols {
        need(pos, 1)?;
        let tag = buf[*pos];
        *pos += 1;
        match tag {
            0 => {
                need(pos, 8)?;
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                row.push(KeyVal::I64(i64::from_le_bytes(b)));
            }
            1 => {
                need(pos, 1)?;
                row.push(KeyVal::Bool(buf[*pos] != 0));
                *pos += 1;
            }
            2 => {
                need(pos, 4)?;
                let mut b = [0u8; 4];
                b.copy_from_slice(&buf[*pos..*pos + 4]);
                *pos += 4;
                let len = u32::from_le_bytes(b) as usize;
                need(pos, len)?;
                let s = String::from_utf8_lossy(&buf[*pos..*pos + len]).into_owned();
                *pos += len;
                row.push(KeyVal::Str(s));
            }
            3 => row.push(KeyVal::Null),
            t => bail!("key row decode: bad tag {t}"),
        }
    }
    Ok(row)
}

/// Wire-encode the key cells of row `i` of `cols` — byte-identical to
/// [`encode_key_row`] on the materialized tuple, without building it.
pub fn encode_key_cells(cols: &[&Column], i: usize, buf: &mut Vec<u8>) {
    let masks: Vec<Option<&ValidityMask>> = vec![None; cols.len()];
    encode_key_cells_nullable(cols, &masks, i, buf);
}

/// [`encode_key_cells`] over nullable key columns: invalid cells encode as
/// the null tag, matching [`encode_key_row`] on [`KeyVal::Null`].
pub fn encode_key_cells_nullable(
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
    i: usize,
    buf: &mut Vec<u8>,
) {
    for (c, mask) in cols.iter().zip(masks) {
        if let Some(m) = mask {
            if !m.get(i) {
                buf.push(3);
                continue;
            }
        }
        match c {
            Column::I64(v) => {
                buf.push(0);
                buf.extend_from_slice(&v[i].to_le_bytes());
            }
            Column::Bool(v) => {
                buf.push(1);
                buf.push(v[i] as u8);
            }
            Column::Str(v) => {
                buf.push(2);
                buf.extend_from_slice(&(v[i].len() as u32).to_le_bytes());
                buf.extend_from_slice(v[i].as_bytes());
            }
            Column::F64(_) => panic!("Float64 cannot be a relational key"),
        }
    }
}

/// Advance `pos` past an `ncols`-cell key tuple written by
/// [`encode_key_row`] without materializing it (pre-aggregation merge keys
/// stay raw bytes).
pub fn skip_key_row(ncols: usize, buf: &[u8], pos: &mut usize) -> Result<()> {
    let need = |pos: &usize, n: usize| -> Result<()> {
        if *pos + n > buf.len() {
            bail!("key row skip: truncated buffer");
        }
        Ok(())
    };
    for _ in 0..ncols {
        need(pos, 1)?;
        let tag = buf[*pos];
        *pos += 1;
        match tag {
            0 => {
                need(pos, 8)?;
                *pos += 8;
            }
            1 => {
                need(pos, 1)?;
                *pos += 1;
            }
            2 => {
                need(pos, 4)?;
                let mut b = [0u8; 4];
                b.copy_from_slice(&buf[*pos..*pos + 4]);
                *pos += 4;
                let len = u32::from_le_bytes(b) as usize;
                need(pos, len)?;
                *pos += len;
            }
            3 => {}
            t => bail!("key row skip: bad tag {t}"),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Packed composite keys — the fast path.
// ---------------------------------------------------------------------------

/// Sign-flipped big-endian encoding of an i64: byte-wise lexicographic
/// comparison of the result equals integer comparison.
#[inline]
fn pack_i64_be(x: i64) -> [u8; 8] {
    ((x as u64) ^ (1u64 << 63)).to_be_bytes()
}

/// Order-preserving string cell encoding: each 0x00 data byte becomes
/// `0x00 0x01` and the cell ends with a `0x00 0x00` terminator. Byte-wise
/// comparison of whole rows then equals tuple comparison even when the cell
/// is followed by further cells: at the first divergence either the data
/// bytes differ directly, or the terminator (`0x00 0x00`) loses to an escape
/// (`0x00 0x01`) and to any real byte — i.e. a proper prefix string sorts
/// first, before any following cell is ever inspected.
fn escape_str_into(s: &str, out: &mut Vec<u8>) {
    for &b in s.as_bytes() {
        if b == 0 {
            out.push(0);
            out.push(1);
        } else {
            out.push(b);
        }
    }
    out.push(0);
    out.push(0);
}

/// Shared fixed-width packing loop (Int64/Bool columns only): concatenated
/// order-preserving cells, with optional per-column bit inversion (the
/// descending directions of [`SortKeys`]; missing entries mean no
/// inversion). With `with_flags`, every cell is preceded by a validity flag
/// byte (0 = null, 1 = valid) so byte order places nulls *before* all
/// values — and the inversion covers the flag too, so descending columns
/// order nulls last. Null cells pack the canonical default value bytes, so
/// two nulls compare equal. Returns `(row_width, packed_rows)`.
fn pack_fixed(
    cols: &[&Column],
    masks: &[Option<&ValidityMask>],
    with_flags: bool,
    invert: &[bool],
) -> (usize, Vec<u8>) {
    let n = cols.first().map_or(0, |c| c.len());
    let flag = usize::from(with_flags);
    let width: usize = cols
        .iter()
        .map(|c| {
            flag + match c.dtype() {
                DType::I64 => 8,
                _ => 1,
            }
        })
        .sum();
    let mut data = vec![0u8; n * width];
    let mut off = 0usize;
    for (k, &c) in cols.iter().enumerate() {
        let inv = invert.get(k).copied().unwrap_or(false);
        let mask = masks.get(k).copied().flatten();
        let valid = |i: usize| mask.map_or(true, |m| m.get(i));
        match c {
            Column::I64(v) => {
                for (i, &x) in v.iter().enumerate() {
                    let ok = valid(i);
                    let mut b = pack_i64_be(if ok { x } else { 0 });
                    let at = i * width + off;
                    if with_flags {
                        data[at] = if inv { !(ok as u8) } else { ok as u8 };
                    }
                    if inv {
                        for byte in &mut b {
                            *byte = !*byte;
                        }
                    }
                    data[at + flag..at + flag + 8].copy_from_slice(&b);
                }
                off += flag + 8;
            }
            Column::Bool(v) => {
                for (i, &x) in v.iter().enumerate() {
                    let ok = valid(i);
                    let b = (ok && x) as u8;
                    let at = i * width + off;
                    if with_flags {
                        data[at] = if inv { !(ok as u8) } else { ok as u8 };
                    }
                    data[at + flag] = if inv { !b } else { b };
                }
                off += flag + 1;
            }
            _ => unreachable!("fixed packing requires Int64/Bool columns"),
        }
    }
    (width, data)
}

/// A whole key column set, packed once per operator. See the module docs for
/// the three layouts. All accessors are per-row and allocation-free; two
/// `PackedKeys` built from dtype-identical column lists (the two sides of a
/// join) are mutually comparable.
pub enum PackedKeys<'a> {
    /// Single Int64 key column — zero-copy borrow, the seed's fast path.
    I64(&'a [i64]),
    /// Multi-column Int64/Bool keys: fixed-width order-preserving rows
    /// (`data[i*width .. (i+1)*width]`).
    Fixed { width: usize, data: Vec<u8> },
    /// Keys containing String columns: variable-width order-preserving rows
    /// with per-operator string interning (each distinct string is escaped
    /// once).
    Bytes { offsets: Vec<usize>, data: Vec<u8> },
    /// Single String key column, dictionary-encoded: `dict[k]` is the exact
    /// `Bytes`-layout encoding of one distinct row value and `hashes[k]` its
    /// fx hash, so hashing/equality/order agree byte-for-byte with the
    /// `Bytes` layout (the two are mutually comparable) while hashing costs
    /// one lookup per row instead of one escaped-byte hash.
    Dict {
        codes: Vec<u32>,
        dict: Vec<Vec<u8>>,
        hashes: Vec<u64>,
    },
}

impl<'a> PackedKeys<'a> {
    /// Pack non-nullable key columns (all equal length; Float64 rejected).
    pub fn pack(cols: &[&'a Column]) -> Result<PackedKeys<'a>> {
        let masks: Vec<Option<&ValidityMask>> = vec![None; cols.len()];
        Self::pack_masked(cols, &masks, false)
    }

    /// Pack possibly-nullable key columns. The flagged layout is used only
    /// when a mask is actually present, so fully-valid key sets keep the
    /// zero-copy / plain layouts.
    pub fn pack_nullable(
        cols: &[&'a Column],
        masks: &[Option<&'a ValidityMask>],
    ) -> Result<PackedKeys<'a>> {
        Self::pack_masked(cols, masks, masks.iter().any(|m| m.is_some()))
    }

    /// Pack with an explicit layout choice: `with_flags` prefixes every cell
    /// with a validity flag byte (0 = null sorts first, 1 = valid). The two
    /// sides of a join must agree on `with_flags` (pass
    /// `left_has_mask || right_has_mask`) so their rows stay mutually
    /// comparable.
    pub fn pack_masked(
        cols: &[&'a Column],
        masks: &[Option<&'a ValidityMask>],
        with_flags: bool,
    ) -> Result<PackedKeys<'a>> {
        debug_assert_eq!(cols.len(), masks.len());
        if cols.iter().any(|c| c.dtype() == DType::F64) {
            bail!("Float64 cannot be a relational key");
        }
        if !with_flags && cols.len() == 1 {
            if let Column::I64(v) = cols[0] {
                return Ok(PackedKeys::I64(v.as_slice()));
            }
        }
        let n = cols.first().map_or(0, |c| c.len());
        debug_assert!(cols.iter().all(|c| c.len() == n));
        if cols.iter().all(|c| matches!(c.dtype(), DType::I64 | DType::Bool)) {
            let (width, data) = pack_fixed(cols, masks, with_flags, &[]);
            return Ok(PackedKeys::Fixed { width, data });
        }
        // Single String key column: dictionary-encode. Each dict entry is the
        // exact Bytes-layout row encoding (flag byte + escaped string when
        // flagged, escaped string alone otherwise), built and hashed once per
        // distinct value; rows carry u32 codes.
        if cols.len() == 1 {
            if let Column::Str(v) = cols[0] {
                let mask = masks[0];
                let mut by_str: FxHashMap<&'a str, u32> = FxHashMap::default();
                let mut null_code: Option<u32> = None;
                let mut dict: Vec<Vec<u8>> = Vec::new();
                let mut hashes: Vec<u64> = Vec::new();
                let mut codes: Vec<u32> = Vec::with_capacity(n);
                let push_entry =
                    |dict: &mut Vec<Vec<u8>>, hashes: &mut Vec<u64>, entry: Vec<u8>| {
                        hashes.push(fxhash::hash_bytes(&entry));
                        dict.push(entry);
                        (dict.len() - 1) as u32
                    };
                for (i, s) in v.iter().enumerate() {
                    let ok = mask.map_or(true, |m| m.get(i));
                    let code = if with_flags && !ok {
                        *null_code.get_or_insert_with(|| {
                            push_entry(&mut dict, &mut hashes, vec![0u8])
                        })
                    } else {
                        match by_str.entry(s.as_str()) {
                            std::collections::hash_map::Entry::Occupied(e) => *e.get(),
                            std::collections::hash_map::Entry::Vacant(e) => {
                                let mut enc = Vec::new();
                                if with_flags {
                                    enc.push(1u8);
                                }
                                escape_str_into(s, &mut enc);
                                *e.insert(push_entry(&mut dict, &mut hashes, enc))
                            }
                        }
                    };
                    codes.push(code);
                }
                return Ok(PackedKeys::Dict {
                    codes,
                    dict,
                    hashes,
                });
            }
        }
        // String fallback: variable-width rows; intern each distinct string's
        // escaped encoding once for this operator. Null cells are the flag
        // byte alone — comparison decides at the flag, then continues into
        // the next cell.
        let mut interned: FxHashMap<&'a str, Vec<u8>> = FxHashMap::default();
        let mut data: Vec<u8> = Vec::new();
        let mut offsets: Vec<usize> = Vec::with_capacity(n + 1);
        offsets.push(0);
        for i in 0..n {
            for (ci, &c) in cols.iter().enumerate() {
                let ok = masks[ci].map_or(true, |m| m.get(i));
                if with_flags {
                    data.push(ok as u8);
                    if !ok {
                        continue;
                    }
                }
                match c {
                    Column::I64(v) => data.extend_from_slice(&pack_i64_be(v[i])),
                    Column::Bool(v) => data.push(v[i] as u8),
                    Column::Str(v) => {
                        let enc = interned.entry(v[i].as_str()).or_insert_with(|| {
                            let mut e = Vec::new();
                            escape_str_into(&v[i], &mut e);
                            e
                        });
                        data.extend_from_slice(enc);
                    }
                    Column::F64(_) => unreachable!("rejected above"),
                }
            }
            offsets.push(data.len());
        }
        Ok(PackedKeys::Bytes { offsets, data })
    }

    pub fn len(&self) -> usize {
        match self {
            PackedKeys::I64(v) => v.len(),
            PackedKeys::Fixed { width, data } => {
                if *width == 0 {
                    0
                } else {
                    data.len() / width
                }
            }
            PackedKeys::Bytes { offsets, .. } => offsets.len() - 1,
            PackedKeys::Dict { codes, .. } => codes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Byte view of one packed row (Fixed/Bytes layouts only).
    #[inline]
    fn row_bytes(&self, i: usize) -> &[u8] {
        match self {
            PackedKeys::I64(_) => unreachable!("I64 layout has no byte rows"),
            PackedKeys::Fixed { width, data } => &data[i * width..(i + 1) * width],
            PackedKeys::Bytes { offsets, data } => &data[offsets[i]..offsets[i + 1]],
            PackedKeys::Dict { codes, dict, .. } => &dict[codes[i] as usize],
        }
    }

    /// Fx hash of row `i` — deterministic, so equal tuples land on the same
    /// rank no matter which rank (or side of a join) hashed them.
    #[inline]
    pub fn hash_row(&self, i: usize) -> u64 {
        match self {
            PackedKeys::I64(v) => fxhash::hash_u64(v[i] as u64),
            PackedKeys::Dict { codes, hashes, .. } => hashes[codes[i] as usize],
            _ => fxhash::hash_bytes(self.row_bytes(i)),
        }
    }

    /// Destination rank of row `i`.
    #[inline]
    pub fn owner(&self, i: usize, nranks: usize) -> usize {
        (self.hash_row(i) % nranks as u64) as usize
    }

    /// Destination rank of every row (the shuffle routing vector).
    pub fn owners(&self, nranks: usize) -> Vec<usize> {
        (0..self.len()).map(|i| self.owner(i, nranks)).collect()
    }

    /// Tuple equality between row `i` of `self` and row `j` of `other`
    /// (layouts must match — guaranteed for dtype-identical key lists).
    #[inline]
    pub fn eq_rows(&self, i: usize, other: &PackedKeys, j: usize) -> bool {
        match (self, other) {
            (PackedKeys::I64(a), PackedKeys::I64(b)) => a[i] == b[j],
            // Dict rows carry exact Bytes-layout encodings, so the two string
            // layouts are mutually comparable (a join may dict-encode one
            // side only, e.g. when one side's strings are low-cardinality).
            (PackedKeys::Fixed { .. }, PackedKeys::Fixed { .. })
            | (
                PackedKeys::Bytes { .. } | PackedKeys::Dict { .. },
                PackedKeys::Bytes { .. } | PackedKeys::Dict { .. },
            ) => self.row_bytes(i) == other.row_bytes(j),
            _ => panic!("packed key layout mismatch"),
        }
    }

    /// Ascending tuple order between row `i` of `self` and row `j` of
    /// `other` — agrees with [`cmp_key_rows`] under all-ascending orders.
    #[inline]
    pub fn cmp_rows(&self, i: usize, other: &PackedKeys, j: usize) -> Ordering {
        match (self, other) {
            (PackedKeys::I64(a), PackedKeys::I64(b)) => a[i].cmp(&b[j]),
            (PackedKeys::Fixed { .. }, PackedKeys::Fixed { .. })
            | (
                PackedKeys::Bytes { .. } | PackedKeys::Dict { .. },
                PackedKeys::Bytes { .. } | PackedKeys::Dict { .. },
            ) => self.row_bytes(i).cmp(other.row_bytes(j)),
            _ => panic!("packed key layout mismatch"),
        }
    }

    /// Append the canonical byte encoding of row `i` — the wire form of one
    /// key tuple in *this* layout (the skew sampling pass ships these
    /// through its allgather). Two `PackedKeys` over dtype-identical column
    /// lists with the same flag choice encode equal tuples identically, so
    /// the bytes are comparable across the two sides of a join and across
    /// ranks.
    pub fn append_row_bytes(&self, i: usize, buf: &mut Vec<u8>) {
        match self {
            PackedKeys::I64(v) => buf.extend_from_slice(&v[i].to_le_bytes()),
            _ => buf.extend_from_slice(self.row_bytes(i)),
        }
    }

    /// Does row `i` equal a tuple previously encoded by
    /// [`PackedKeys::append_row_bytes`] on this layout? Allocation-free —
    /// the heavy-set membership test of the skew-aware join.
    #[inline]
    pub fn row_matches(&self, i: usize, encoded: &[u8]) -> bool {
        match self {
            PackedKeys::I64(v) => encoded == v[i].to_le_bytes().as_slice(),
            _ => encoded == self.row_bytes(i),
        }
    }

    /// [`PackedKeys::hash_row`] of an *encoded* tuple (see
    /// [`PackedKeys::append_row_bytes`]): hashes a foreign row exactly as a
    /// local row of this layout would hash, so heavy-set membership agrees
    /// on every rank and on both join sides.
    pub fn hash_encoded_row(&self, encoded: &[u8]) -> u64 {
        match self {
            PackedKeys::I64(_) => {
                let v = i64::from_le_bytes(
                    encoded.try_into().expect("encoded i64 key: 8 bytes"),
                );
                fxhash::hash_u64(v as u64)
            }
            _ => fxhash::hash_bytes(encoded),
        }
    }
}

/// Dense group ids over a packed key set: `group_of_row[i]` is the group of
/// row `i`, `rep_rows[g]` one representative row of group `g`. Group ids are
/// assigned in first-seen row order.
pub struct KeyGroups {
    pub group_of_row: Vec<u32>,
    pub rep_rows: Vec<u32>,
}

impl KeyGroups {
    pub fn num_groups(&self) -> usize {
        self.rep_rows.len()
    }
}

/// Hash-group the rows of a packed key set (the group-by inner loop). The
/// table maps hashes to candidate groups; tuple equality against the group
/// representative resolves collisions, so no per-row key is ever
/// materialized.
pub fn group_packed(keys: &PackedKeys) -> KeyGroups {
    let n = keys.len();
    let mut table: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
    let mut group_of_row: Vec<u32> = Vec::with_capacity(n);
    let mut rep_rows: Vec<u32> = Vec::new();
    for i in 0..n {
        let h = keys.hash_row(i);
        let gids = table.entry(h).or_default();
        let mut found = None;
        for &g in gids.iter() {
            if keys.eq_rows(i, keys, rep_rows[g as usize] as usize) {
                found = Some(g);
                break;
            }
        }
        let g = match found {
            Some(g) => g,
            None => {
                let g = rep_rows.len() as u32;
                rep_rows.push(i as u32);
                gids.push(g);
                g
            }
        };
        group_of_row.push(g);
    }
    KeyGroups {
        group_of_row,
        rep_rows,
    }
}

/// Fixed-width, direction-aware packed sort keys: byte-wise row comparison
/// equals [`cmp_key_rows`] under `orders`. Descending columns are packed
/// bit-inverted. Returns `None` when a String key column forces the KeyRow
/// fallback (variable-width cells are not safely invertible).
pub struct SortKeys {
    width: usize,
    data: Vec<u8>,
    len: usize,
}

impl SortKeys {
    /// Pack `cols` under `orders` (missing directions default to ascending).
    /// `Ok(None)` = String key present, use the KeyRow path.
    pub fn pack(cols: &[&Column], orders: &[SortOrder]) -> Result<Option<SortKeys>> {
        let masks: Vec<Option<&ValidityMask>> = vec![None; cols.len()];
        Self::pack_nullable(cols, &masks, orders, false)
    }

    /// [`SortKeys::pack`] over nullable key columns. `with_flags` must be
    /// true whenever *any* rank's chunk of the key set can carry a mask
    /// (decided from the static schema), so the packed row width — the
    /// splitter wire format — is identical on every rank. Flag bytes invert
    /// with their column's direction: ascending orders nulls first,
    /// descending orders them last.
    pub fn pack_nullable(
        cols: &[&Column],
        masks: &[Option<&ValidityMask>],
        orders: &[SortOrder],
        with_flags: bool,
    ) -> Result<Option<SortKeys>> {
        if cols.iter().any(|c| c.dtype() == DType::F64) {
            bail!("Float64 cannot be a relational key");
        }
        if cols.iter().any(|c| c.dtype() == DType::Str) {
            return Ok(None);
        }
        let n = cols.first().map_or(0, |c| c.len());
        let invert: Vec<bool> = (0..cols.len())
            .map(|k| {
                matches!(
                    orders.get(k).copied().unwrap_or(SortOrder::Asc),
                    SortOrder::Desc
                )
            })
            .collect();
        let with_flags = with_flags || masks.iter().any(|m| m.is_some());
        let (width, data) = pack_fixed(cols, masks, with_flags, &invert);
        Ok(Some(SortKeys {
            width,
            data,
            len: n,
        }))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Row width in bytes — a pure function of the key schema, so every rank
    /// agrees on it (splitter wire format).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Byte view of one packed row; `row(a).cmp(row(b))` is the sort order.
    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    /// Gather rows into a new `SortKeys` (reorder after an argsort).
    pub fn take(&self, idx: &[usize]) -> SortKeys {
        let mut data = Vec::with_capacity(idx.len() * self.width);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        SortKeys {
            width: self.width,
            data,
            len: idx.len(),
        }
    }

    /// Number of rows in the sorted range `[start, len)` whose packed bytes
    /// are `<= limit` (range-partition upper bound against a splitter).
    pub fn partition_le(&self, start: usize, limit: &[u8]) -> usize {
        let mut lo = start;
        let mut hi = self.len;
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.row(mid) <= limit {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo - start
    }

    /// *Local-only* packed sort keys built from materialized key tuples:
    /// String cells are dictionary-encoded with an order-preserving
    /// per-column dictionary (sorted distinct strings, code = rank, packed
    /// big-endian), so rows stay fixed-width and radix-sortable even for
    /// string keys. Byte order of the rows equals [`cmp_key_rows`] under
    /// `orders`. The codes are assigned from *this* tuple set — never ship
    /// these rows or compare them against another `SortKeys` instance.
    pub fn from_key_rows(krows: &[KeyRow], orders: &[SortOrder]) -> SortKeys {
        #[derive(Clone, Copy, PartialEq)]
        enum Shape {
            Empty,
            I64,
            Bool,
            Str,
        }
        let n = krows.len();
        let ncols = krows.first().map_or(0, |r| r.len());
        let mut shapes = vec![Shape::Empty; ncols];
        let mut has_null = vec![false; ncols];
        for row in krows {
            for (k, cell) in row.iter().enumerate() {
                match cell {
                    KeyVal::Null => has_null[k] = true,
                    KeyVal::I64(_) => shapes[k] = Shape::I64,
                    KeyVal::Bool(_) => shapes[k] = Shape::Bool,
                    KeyVal::Str(_) => shapes[k] = Shape::Str,
                }
            }
        }
        // order-preserving dictionary per String column: sorted distinct
        // strings, code = rank — u32 big-endian code order == string order
        let dicts: Vec<Option<FxHashMap<&str, u32>>> = (0..ncols)
            .map(|k| {
                if shapes[k] != Shape::Str {
                    return None;
                }
                let mut distinct: Vec<&str> = krows
                    .iter()
                    .filter_map(|r| match &r[k] {
                        KeyVal::Str(s) => Some(s.as_str()),
                        _ => None,
                    })
                    .collect();
                distinct.sort_unstable();
                distinct.dedup();
                Some(
                    distinct
                        .into_iter()
                        .enumerate()
                        .map(|(rank, s)| (s, rank as u32))
                        .collect(),
                )
            })
            .collect();
        let cell_width = |k: usize| {
            usize::from(has_null[k])
                + match shapes[k] {
                    Shape::Empty => 0,
                    Shape::I64 => 8,
                    Shape::Bool => 1,
                    Shape::Str => 4,
                }
        };
        let width: usize = (0..ncols).map(cell_width).sum();
        let mut data = vec![0u8; n * width];
        for (i, row) in krows.iter().enumerate() {
            let mut at = i * width;
            for (k, cell) in row.iter().enumerate() {
                let flag = usize::from(has_null[k]);
                let cw = cell_width(k);
                let out = &mut data[at..at + cw];
                if flag == 1 {
                    out[0] = !cell.is_null() as u8;
                }
                match cell {
                    KeyVal::Null => {} // value lane stays zero; nulls compare equal
                    KeyVal::I64(x) => out[flag..].copy_from_slice(&pack_i64_be(*x)),
                    KeyVal::Bool(b) => out[flag] = *b as u8,
                    KeyVal::Str(s) => out[flag..].copy_from_slice(
                        &dicts[k].as_ref().expect("Str column has a dictionary")[s.as_str()]
                            .to_be_bytes(),
                    ),
                }
                if matches!(
                    orders.get(k).copied().unwrap_or(SortOrder::Asc),
                    SortOrder::Desc
                ) {
                    for b in out {
                        *b = !*b;
                    }
                }
                at += cw;
            }
        }
        SortKeys {
            width,
            data,
            len: n,
        }
    }

    /// Stable argsort of all rows — radix or comparison by the crossover
    /// heuristic; both paths are stable, so the permutation is identical.
    pub fn argsort(&self) -> Vec<usize> {
        self.argsort_range(0, self.len)
    }

    /// Stable argsort of the row range `[start, end)` (the external-merge
    /// run sort works on contiguous slices). Returned indices are global.
    pub fn argsort_range(&self, start: usize, end: usize) -> Vec<usize> {
        if radix_wins(end - start, self.width) {
            self.radix_argsort_range(start, end)
        } else {
            self.comparison_argsort_range(start, end)
        }
    }

    /// The comparison fallback: stable `sort_by` over packed row bytes.
    pub fn comparison_argsort(&self) -> Vec<usize> {
        self.comparison_argsort_range(0, self.len)
    }

    fn comparison_argsort_range(&self, start: usize, end: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (start..end).collect();
        idx.sort_by(|&a, &b| self.row(a).cmp(self.row(b)));
        idx
    }

    /// LSD radix argsort: one stable counting sort per byte position, least
    /// significant (rightmost) first, over the fixed-width packed rows.
    /// Because the rows are order-preserving byte encodings, the final
    /// lexicographic byte order *is* the sort order, and per-pass stability
    /// makes the whole argsort stable — byte-identical to
    /// [`SortKeys::comparison_argsort`]. Passes whose byte is constant
    /// across the range (flag bytes, high bytes of small ints) are skipped.
    pub fn radix_argsort(&self) -> Vec<usize> {
        self.radix_argsort_range(0, self.len)
    }

    fn radix_argsort_range(&self, start: usize, end: usize) -> Vec<usize> {
        let n = end - start;
        let w = self.width;
        let mut cur: Vec<usize> = (start..end).collect();
        if n <= 1 || w == 0 {
            return cur;
        }
        let mut nxt: Vec<usize> = vec![0; n];
        for b in (0..w).rev() {
            let mut counts = [0usize; 256];
            for &i in &cur {
                counts[self.data[i * w + b] as usize] += 1;
            }
            // constant byte column ⇒ the pass is a stable no-op
            if counts[self.data[cur[0] * w + b] as usize] == n {
                continue;
            }
            let mut offs = [0usize; 256];
            let mut acc = 0usize;
            for (o, &c) in offs.iter_mut().zip(&counts) {
                *o = acc;
                acc += c;
            }
            for &i in &cur {
                let slot = &mut offs[self.data[i * w + b] as usize];
                nxt[*slot] = i;
                *slot += 1;
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        cur
    }
}

/// Crossover heuristic between the LSD radix argsort (cost ≈ `width · (n +
/// 256)`) and the comparison argsort (cost ≈ `n · log n` memcmps of up to
/// `width` bytes): radix needs enough rows to amortize its per-pass
/// histograms and loses on very wide rows. Both sides are stable, so the
/// choice never changes the output.
fn radix_wins(n: usize, width: usize) -> bool {
    if n < 64 || width == 0 {
        return false;
    }
    let log_n = usize::BITS as usize - n.leading_zeros() as usize;
    width * (n + 256) < 4 * n * log_n
}

/// Rebuild key columns (one per key position) from key tuples, pushing in
/// row order. `templates` supplies the dtype of each position; null cells
/// push the dtype default and clear the validity bit.
pub fn key_columns(rows: &[KeyRow], templates: &[&Column]) -> Vec<NullableColumn> {
    let mut cols: Vec<Column> = templates
        .iter()
        .map(|c| Column::new_empty(c.dtype()))
        .collect();
    let mut masks: Vec<ValidityMask> = templates
        .iter()
        .map(|_| ValidityMask::new_null(0))
        .collect();
    for row in rows {
        for ((col, mask), cell) in cols.iter_mut().zip(masks.iter_mut()).zip(row) {
            let v = cell.to_value_typed(col.dtype());
            crate::column::push_nullable(col, mask, &v);
        }
    }
    cols.into_iter()
        .zip(masks)
        .map(|(c, m)| NullableColumn::new(c, Some(m)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_rows_and_hash() {
        let a = Column::I64(vec![1, 1, 2]);
        let b = Column::Str(vec!["x".into(), "y".into(), "x".into()]);
        let rows = key_rows(&[&a, &b]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec![KeyVal::I64(1), KeyVal::Str("x".into())]);
        assert_ne!(hash_key_row(&rows[0]), hash_key_row(&rows[1]));
        assert_eq!(hash_key_row(&rows[0]), hash_key_row(&rows[0].clone()));
        assert!(key_rows(&[&Column::F64(vec![1.0])]).is_err());
    }

    #[test]
    fn cmp_with_directions() {
        let a = vec![KeyVal::I64(1), KeyVal::I64(9)];
        let b = vec![KeyVal::I64(1), KeyVal::I64(3)];
        use crate::types::SortOrder::*;
        assert_eq!(cmp_key_rows(&a, &b, &[Asc, Asc]), Ordering::Greater);
        assert_eq!(cmp_key_rows(&a, &b, &[Asc, Desc]), Ordering::Less);
        assert_eq!(cmp_key_rows(&a, &a, &[Desc, Desc]), Ordering::Equal);
        // first column dominates
        let c = vec![KeyVal::I64(0), KeyVal::I64(100)];
        assert_eq!(cmp_key_rows(&c, &b, &[Desc, Asc]), Ordering::Greater);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let row = vec![
            KeyVal::I64(-7),
            KeyVal::Bool(true),
            KeyVal::Str("hello".into()),
        ];
        let mut buf = Vec::new();
        encode_key_row(&row, &mut buf);
        encode_key_row(&row, &mut buf);
        let mut pos = 0;
        assert_eq!(decode_key_row(3, &buf, &mut pos).unwrap(), row);
        assert_eq!(decode_key_row(3, &buf, &mut pos).unwrap(), row);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn decode_truncated_errors_not_panics() {
        let row = vec![KeyVal::I64(42), KeyVal::Str("abcdef".into())];
        let mut buf = Vec::new();
        encode_key_row(&row, &mut buf);
        // every strict prefix must produce Err, never a panic
        for cut in 0..buf.len() {
            let mut pos = 0;
            assert!(
                decode_key_row(2, &buf[..cut], &mut pos).is_err(),
                "cut={cut}"
            );
        }
        // asking for more cells than encoded also errors
        let mut pos = 0;
        assert!(decode_key_row(3, &buf, &mut pos).is_err());
    }

    #[test]
    fn key_columns_rebuild() {
        let a = Column::I64(vec![4, 2]);
        let b = Column::Str(vec!["p".into(), "q".into()]);
        let rows = key_rows(&[&a, &b]).unwrap();
        let cols = key_columns(&rows, &[&a, &b]);
        assert_eq!(cols[0].values, a);
        assert!(cols[0].validity.is_none());
        assert_eq!(cols[1].values, b);
        // null cells round-trip as default value + cleared bit
        let rows = vec![
            vec![KeyVal::Null, KeyVal::Str("p".into())],
            vec![KeyVal::I64(7), KeyVal::Null],
        ];
        let cols = key_columns(&rows, &[&a, &b]);
        assert_eq!(cols[0].values.as_i64(), &[0, 7]);
        assert_eq!(cols[0].validity.as_ref().unwrap().to_bools(), vec![false, true]);
        assert_eq!(cols[1].values.as_str_col(), &["p".to_string(), "".into()]);
        assert_eq!(cols[1].validity.as_ref().unwrap().to_bools(), vec![true, false]);
    }

    #[test]
    fn null_keyval_orders_first_and_roundtrips() {
        // derived Ord: Null before every value
        assert!(KeyVal::Null < KeyVal::I64(i64::MIN));
        assert!(KeyVal::Null < KeyVal::Bool(false));
        assert!(KeyVal::Null < KeyVal::Str(String::new()));
        assert_eq!(KeyVal::Null, KeyVal::Null);
        // wire roundtrip incl. the null tag
        let row = vec![KeyVal::Null, KeyVal::I64(-3), KeyVal::Null, KeyVal::Str("x".into())];
        let mut buf = Vec::new();
        encode_key_row(&row, &mut buf);
        let mut pos = 0;
        assert_eq!(decode_key_row(4, &buf, &mut pos).unwrap(), row);
        assert_eq!(pos, buf.len());
        let mut pos = 0;
        skip_key_row(4, &buf, &mut pos).unwrap();
        assert_eq!(pos, buf.len());
        // Value conversion
        assert_eq!(
            KeyVal::from_value(&Value::Null(DType::I64)).unwrap(),
            KeyVal::Null
        );
        assert_eq!(
            KeyVal::Null.to_value_typed(DType::Str),
            Value::Null(DType::Str)
        );
    }

    #[test]
    fn nullable_packed_agrees_with_nullable_key_rows() {
        use crate::column::ValidityMask;
        // every dtype, nulls scattered; values under nulls pre-scrubbed to
        // defaults (the canonical form the operators maintain)
        let a = Column::I64(vec![0, -1, 0, 1, i64::MAX, 0]);
        let am = ValidityMask::from_bools(&[false, true, true, true, true, false]);
        let b = Column::Bool(vec![false, false, true, true, false, false]);
        let bm = ValidityMask::from_bools(&[false, true, true, true, false, true]);
        let s = Column::Str(vec![
            "".into(),
            "a".into(),
            "".into(),
            "a\0b".into(),
            "".into(),
            "z".into(),
        ]);
        let sm = ValidityMask::from_bools(&[true, true, false, true, false, true]);
        let cases: Vec<(Vec<&Column>, Vec<Option<&ValidityMask>>)> = vec![
            (vec![&a], vec![Some(&am)]),
            (vec![&a, &b], vec![Some(&am), Some(&bm)]),
            (vec![&a, &b], vec![None, Some(&bm)]),
            (vec![&a, &s], vec![Some(&am), Some(&sm)]),
            (vec![&a, &b, &s], vec![Some(&am), None, Some(&sm)]),
        ];
        for (cols, masks) in cases {
            let packed = PackedKeys::pack_nullable(&cols, &masks).unwrap();
            let rows = key_rows_nullable(&cols, &masks).unwrap();
            assert_eq!(packed.len(), rows.len());
            for i in 0..rows.len() {
                for j in 0..rows.len() {
                    assert_eq!(
                        packed.eq_rows(i, &packed, j),
                        rows[i] == rows[j],
                        "eq {i},{j} ({} cols)",
                        cols.len()
                    );
                    assert_eq!(
                        packed.cmp_rows(i, &packed, j),
                        cmp_key_rows(&rows[i], &rows[j], &[]),
                        "cmp {i},{j} ({} cols)",
                        cols.len()
                    );
                    if rows[i] == rows[j] {
                        assert_eq!(packed.hash_row(i), packed.hash_row(j));
                        assert_eq!(packed.owner(i, 5), packed.owner(j, 5));
                    }
                }
            }
        }
    }

    #[test]
    fn nullable_packed_layouts_and_cross_side_flag() {
        use crate::column::ValidityMask;
        let a = Column::I64(vec![1, 0]);
        let am = ValidityMask::from_bools(&[true, false]);
        // mask present → flagged Fixed layout even for a single i64 key
        assert!(matches!(
            PackedKeys::pack_nullable(&[&a], &[Some(&am)]).unwrap(),
            PackedKeys::Fixed { width: 9, .. }
        ));
        // no mask → zero-copy layout preserved
        assert!(matches!(
            PackedKeys::pack_nullable(&[&a], &[None]).unwrap(),
            PackedKeys::I64(_)
        ));
        // the two sides of a join must force a common layout: a mask-free
        // side packed with flags is comparable to the masked side
        let l = Column::I64(vec![0, 7]);
        let lp = PackedKeys::pack_masked(&[&l], &[None], true).unwrap();
        let rp = PackedKeys::pack_masked(&[&a], &[Some(&am)], true).unwrap();
        assert!(lp.eq_rows(1, &lp, 1));
        assert!(!lp.eq_rows(0, &rp, 1), "valid 0 must not equal null");
        assert_eq!(rp.cmp_rows(1, &lp, 0), Ordering::Less, "null sorts first");
        assert_eq!(rp.cmp_rows(1, &rp, 1), Ordering::Equal, "null == null");
    }

    #[test]
    fn nullable_sort_keys_direction_aware() {
        use crate::column::ValidityMask;
        let a = Column::I64(vec![0, 5, 0, -2]);
        let am = ValidityMask::from_bools(&[false, true, true, true]);
        use crate::types::SortOrder::*;
        let rows = key_rows_nullable(&[&a], &[Some(&am)]).unwrap();
        for orders in [vec![Asc], vec![Desc]] {
            let sk = SortKeys::pack_nullable(&[&a], &[Some(&am)], &orders, false)
                .unwrap()
                .unwrap();
            for i in 0..4 {
                for j in 0..4 {
                    assert_eq!(
                        sk.row(i).cmp(sk.row(j)),
                        cmp_key_rows(&rows[i], &rows[j], &orders),
                        "{orders:?} {i},{j}"
                    );
                }
            }
        }
        // with_flags=true must widen the row even when this chunk has no
        // mask (cross-rank splitter width agreement)
        let sk = SortKeys::pack_nullable(&[&a], &[None], &[Asc], true)
            .unwrap()
            .unwrap();
        assert_eq!(sk.width(), 9);
    }

    #[test]
    fn encode_key_cells_matches_encode_key_row() {
        let a = Column::I64(vec![-7, 42]);
        let b = Column::Bool(vec![true, false]);
        let c = Column::Str(vec!["hello".into(), "".into()]);
        let cols: Vec<&Column> = vec![&a, &b, &c];
        let rows = key_rows(&cols).unwrap();
        for i in 0..2 {
            let mut via_cells = Vec::new();
            encode_key_cells(&cols, i, &mut via_cells);
            let mut via_row = Vec::new();
            encode_key_row(&rows[i], &mut via_row);
            assert_eq!(via_cells, via_row, "row {i}");
            // skip advances exactly over one tuple
            let mut pos = 0;
            skip_key_row(3, &via_cells, &mut pos).unwrap();
            assert_eq!(pos, via_cells.len());
        }
    }

    #[test]
    fn packed_layout_selection() {
        let i = Column::I64(vec![1, 2]);
        let b = Column::Bool(vec![true, false]);
        let s = Column::Str(vec!["x".into(), "y".into()]);
        assert!(matches!(
            PackedKeys::pack(&[&i]).unwrap(),
            PackedKeys::I64(_)
        ));
        assert!(matches!(
            PackedKeys::pack(&[&i, &b]).unwrap(),
            PackedKeys::Fixed { .. }
        ));
        assert!(matches!(
            PackedKeys::pack(&[&i, &s]).unwrap(),
            PackedKeys::Bytes { .. }
        ));
        assert!(PackedKeys::pack(&[&Column::F64(vec![1.0])]).is_err());
    }

    #[test]
    fn packed_agrees_with_key_rows() {
        // mixed dtypes incl. extremes, empty strings and embedded NULs
        let a = Column::I64(vec![i64::MIN, -1, 0, 1, i64::MAX, 0]);
        let b = Column::Bool(vec![true, false, true, true, false, true]);
        let s = Column::Str(vec![
            "".into(),
            "a".into(),
            "a\0b".into(),
            "a".into(),
            "\0".into(),
            "".into(),
        ]);
        for cols in [vec![&a, &b], vec![&a, &b, &s]] {
            let packed = PackedKeys::pack(&cols).unwrap();
            let rows = key_rows(&cols).unwrap();
            assert_eq!(packed.len(), rows.len());
            for i in 0..rows.len() {
                for j in 0..rows.len() {
                    assert_eq!(
                        packed.eq_rows(i, &packed, j),
                        rows[i] == rows[j],
                        "eq {i},{j}"
                    );
                    assert_eq!(
                        packed.cmp_rows(i, &packed, j),
                        cmp_key_rows(&rows[i], &rows[j], &[]),
                        "cmp {i},{j}"
                    );
                    if rows[i] == rows[j] {
                        assert_eq!(packed.hash_row(i), packed.hash_row(j));
                        assert_eq!(packed.owner(i, 7), packed.owner(j, 7));
                    }
                }
            }
        }
    }

    #[test]
    fn packed_single_i64_is_zero_copy_layout() {
        let a = Column::I64(vec![3, -3, i64::MIN, i64::MAX]);
        let packed = PackedKeys::pack(&[&a]).unwrap();
        assert_eq!(packed.len(), 4);
        assert!(packed.eq_rows(0, &packed, 0));
        assert!(!packed.eq_rows(0, &packed, 1));
        assert_eq!(packed.cmp_rows(2, &packed, 3), Ordering::Less);
        // cross-instance comparability (two join sides)
        let b = Column::I64(vec![-3]);
        let other = PackedKeys::pack(&[&b]).unwrap();
        assert!(packed.eq_rows(1, &other, 0));
        assert_eq!(packed.owner(1, 5), other.owner(0, 5));
    }

    #[test]
    fn row_bytes_roundtrip_all_layouts() {
        use crate::column::ValidityMask;
        let a = Column::I64(vec![5, -5, 5]);
        let b = Column::Bool(vec![true, false, true]);
        let s = Column::Str(vec!["x".into(), "".into(), "x".into()]);
        let am = ValidityMask::from_bools(&[true, false, true]);
        let masks: Vec<Option<&ValidityMask>> = vec![Some(&am)];
        let cases: Vec<PackedKeys> = vec![
            PackedKeys::pack(&[&a]).unwrap(),                      // I64
            PackedKeys::pack(&[&a, &b]).unwrap(),                  // Fixed
            PackedKeys::pack(&[&a, &s]).unwrap(),                  // Bytes
            PackedKeys::pack_masked(&[&a], &masks, true).unwrap(), // flagged
        ];
        for packed in &cases {
            for i in 0..3 {
                let mut enc = Vec::new();
                packed.append_row_bytes(i, &mut enc);
                // encoding identifies the row…
                for j in 0..3 {
                    assert_eq!(
                        packed.row_matches(j, &enc),
                        packed.eq_rows(i, packed, j),
                        "rows {i},{j}"
                    );
                }
                // …and hashes exactly like the row itself
                assert_eq!(packed.hash_encoded_row(&enc), packed.hash_row(i));
            }
        }
    }

    #[test]
    fn group_packed_dense_ids() {
        let a = Column::I64(vec![5, 7, 5, 5, 7, 9]);
        let b = Column::Bool(vec![true, false, true, false, false, true]);
        let packed = PackedKeys::pack(&[&a, &b]).unwrap();
        let g = group_packed(&packed);
        // groups: (5,T)=0, (7,F)=1, (5,F)=2, (9,T)=3 in first-seen order
        assert_eq!(g.group_of_row, vec![0, 1, 0, 2, 1, 3]);
        assert_eq!(g.rep_rows, vec![0, 1, 3, 5]);
        assert_eq!(g.num_groups(), 4);
    }

    #[test]
    fn sort_keys_directions() {
        let a = Column::I64(vec![1, 1, 2, -1]);
        let b = Column::Bool(vec![true, false, true, false]);
        use crate::types::SortOrder::*;
        let rows = key_rows(&[&a, &b]).unwrap();
        for orders in [vec![Asc, Asc], vec![Desc, Asc], vec![Asc, Desc], vec![Desc, Desc]] {
            let sk = SortKeys::pack(&[&a, &b], &orders).unwrap().unwrap();
            for i in 0..4 {
                for j in 0..4 {
                    assert_eq!(
                        sk.row(i).cmp(sk.row(j)),
                        cmp_key_rows(&rows[i], &rows[j], &orders),
                        "{orders:?} {i},{j}"
                    );
                }
            }
        }
        // string keys force the fallback
        let s = Column::Str(vec!["x".into()]);
        assert!(SortKeys::pack(&[&s], &[Asc]).unwrap().is_none());
        assert!(SortKeys::pack(&[&Column::F64(vec![0.0])], &[Asc]).is_err());
    }

    #[test]
    fn sort_keys_take_and_partition() {
        let a = Column::I64(vec![30, 10, 20]);
        let sk = SortKeys::pack(&[&a], &[SortOrder::Asc]).unwrap().unwrap();
        let mut idx: Vec<usize> = (0..3).collect();
        idx.sort_by(|&x, &y| sk.row(x).cmp(sk.row(y)));
        assert_eq!(idx, vec![1, 2, 0]);
        let sorted = sk.take(&idx);
        assert_eq!(sorted.len(), 3);
        assert_eq!(sorted.width(), 8);
        // splitter = packed 20: rows <= 20 from the start of sorted order
        assert_eq!(sorted.partition_le(0, sk.row(2)), 2);
        assert_eq!(sorted.partition_le(2, sk.row(2)), 0);
        assert_eq!(sorted.partition_le(0, sk.row(0)), 3);
    }

    #[test]
    fn value_conversion() {
        assert_eq!(
            KeyVal::from_value(&Value::I64(3)).unwrap().to_value(),
            Value::I64(3)
        );
        assert!(KeyVal::from_value(&Value::F64(1.0)).is_err());
    }
}
