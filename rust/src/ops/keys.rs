//! Composite-key machinery shared by the distributed join / aggregate /
//! sort operators and the baseline engines.
//!
//! A relational key in the redesigned API is a *list* of columns
//! (`on: &[("lk","rk")]`, `aggregate(&["k1","k2"], …)`). At runtime one row's
//! key is a [`KeyVal`] tuple: hashable (routing rows to their owner rank via
//! [`hash_key_row`] — the composite generalization of the paper's
//! `_df_id[i] % npes`), totally ordered (merge comparators, deterministic
//! group output), and wire-encodable (sample-sort splitters, pre-aggregation
//! records). Float64 columns are rejected as keys at plan-typing time, so
//! every key cell has exact equality.

use crate::column::Column;
use crate::fxhash::FxHasher;
use crate::types::{SortOrder, Value};
use anyhow::{bail, Result};
use std::cmp::Ordering;
use std::hash::{BuildHasher, BuildHasherDefault};

/// One cell of a composite key. Variants cover exactly the groupable dtypes.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KeyVal {
    I64(i64),
    Bool(bool),
    Str(String),
}

impl KeyVal {
    /// Convert from a row-engine [`Value`] cell (F64 keys are rejected).
    pub fn from_value(v: &Value) -> Result<KeyVal> {
        Ok(match v {
            Value::I64(x) => KeyVal::I64(*x),
            Value::Bool(x) => KeyVal::Bool(*x),
            Value::Str(x) => KeyVal::Str(x.clone()),
            Value::F64(_) => bail!("Float64 cannot be a relational key"),
        })
    }

    pub fn to_value(&self) -> Value {
        match self {
            KeyVal::I64(x) => Value::I64(*x),
            KeyVal::Bool(x) => Value::Bool(*x),
            KeyVal::Str(x) => Value::Str(x.clone()),
        }
    }
}

/// A full key tuple for one row.
pub type KeyRow = Vec<KeyVal>;

/// Materialize per-row key tuples from the key columns (all equal length).
pub fn key_rows(cols: &[&Column]) -> Result<Vec<KeyRow>> {
    let n = cols.first().map_or(0, |c| c.len());
    let mut out: Vec<KeyRow> = (0..n).map(|_| Vec::with_capacity(cols.len())).collect();
    for c in cols {
        match c {
            Column::I64(v) => {
                for (row, x) in out.iter_mut().zip(v) {
                    row.push(KeyVal::I64(*x));
                }
            }
            Column::Bool(v) => {
                for (row, x) in out.iter_mut().zip(v) {
                    row.push(KeyVal::Bool(*x));
                }
            }
            Column::Str(v) => {
                for (row, x) in out.iter_mut().zip(v) {
                    row.push(KeyVal::Str(x.clone()));
                }
            }
            Column::F64(_) => bail!("Float64 cannot be a relational key"),
        }
    }
    Ok(out)
}

/// Fx hash of one key tuple — the composite-key owner function input.
pub fn hash_key_row(row: &[KeyVal]) -> u64 {
    let b: BuildHasherDefault<FxHasher> = Default::default();
    b.hash_one(row)
}

/// Destination rank of a key tuple.
pub fn owner_of_key(row: &[KeyVal], nranks: usize) -> usize {
    (hash_key_row(row) % nranks as u64) as usize
}

/// Compare two key tuples under per-column sort directions. Missing
/// directions default to ascending (group-by canonical order).
pub fn cmp_key_rows(a: &[KeyVal], b: &[KeyVal], orders: &[SortOrder]) -> Ordering {
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let ord = x.cmp(y);
        let ord = match orders.get(i).copied().unwrap_or(SortOrder::Asc) {
            SortOrder::Asc => ord,
            SortOrder::Desc => ord.reverse(),
        };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

/// Wire-encode one key tuple (tag byte + payload per cell).
pub fn encode_key_row(row: &[KeyVal], buf: &mut Vec<u8>) {
    for v in row {
        match v {
            KeyVal::I64(x) => {
                buf.push(0);
                buf.extend_from_slice(&x.to_le_bytes());
            }
            KeyVal::Bool(x) => {
                buf.push(1);
                buf.push(*x as u8);
            }
            KeyVal::Str(x) => {
                buf.push(2);
                buf.extend_from_slice(&(x.len() as u32).to_le_bytes());
                buf.extend_from_slice(x.as_bytes());
            }
        }
    }
}

/// Decode an `ncols`-cell key tuple written by [`encode_key_row`].
pub fn decode_key_row(ncols: usize, buf: &[u8], pos: &mut usize) -> Result<KeyRow> {
    let need = |pos: &usize, n: usize| -> Result<()> {
        if *pos + n > buf.len() {
            bail!("key row decode: truncated buffer");
        }
        Ok(())
    };
    let mut row = Vec::with_capacity(ncols);
    for _ in 0..ncols {
        need(pos, 1)?;
        let tag = buf[*pos];
        *pos += 1;
        match tag {
            0 => {
                need(pos, 8)?;
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                row.push(KeyVal::I64(i64::from_le_bytes(b)));
            }
            1 => {
                need(pos, 1)?;
                row.push(KeyVal::Bool(buf[*pos] != 0));
                *pos += 1;
            }
            2 => {
                need(pos, 4)?;
                let mut b = [0u8; 4];
                b.copy_from_slice(&buf[*pos..*pos + 4]);
                *pos += 4;
                let len = u32::from_le_bytes(b) as usize;
                need(pos, len)?;
                let s = String::from_utf8_lossy(&buf[*pos..*pos + len]).into_owned();
                *pos += len;
                row.push(KeyVal::Str(s));
            }
            t => bail!("key row decode: bad tag {t}"),
        }
    }
    Ok(row)
}

/// Rebuild key columns (one per key position) from key tuples, pushing in
/// row order. `templates` supplies the dtype of each position.
pub fn key_columns(rows: &[KeyRow], templates: &[&Column]) -> Vec<Column> {
    let mut cols: Vec<Column> = templates
        .iter()
        .map(|c| Column::new_empty(c.dtype()))
        .collect();
    for row in rows {
        for (col, cell) in cols.iter_mut().zip(row) {
            col.push(&cell.to_value());
        }
    }
    cols
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_rows_and_hash() {
        let a = Column::I64(vec![1, 1, 2]);
        let b = Column::Str(vec!["x".into(), "y".into(), "x".into()]);
        let rows = key_rows(&[&a, &b]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec![KeyVal::I64(1), KeyVal::Str("x".into())]);
        assert_ne!(hash_key_row(&rows[0]), hash_key_row(&rows[1]));
        assert_eq!(hash_key_row(&rows[0]), hash_key_row(&rows[0].clone()));
        assert!(key_rows(&[&Column::F64(vec![1.0])]).is_err());
    }

    #[test]
    fn cmp_with_directions() {
        let a = vec![KeyVal::I64(1), KeyVal::I64(9)];
        let b = vec![KeyVal::I64(1), KeyVal::I64(3)];
        use crate::types::SortOrder::*;
        assert_eq!(cmp_key_rows(&a, &b, &[Asc, Asc]), Ordering::Greater);
        assert_eq!(cmp_key_rows(&a, &b, &[Asc, Desc]), Ordering::Less);
        assert_eq!(cmp_key_rows(&a, &a, &[Desc, Desc]), Ordering::Equal);
        // first column dominates
        let c = vec![KeyVal::I64(0), KeyVal::I64(100)];
        assert_eq!(cmp_key_rows(&c, &b, &[Desc, Asc]), Ordering::Greater);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let row = vec![
            KeyVal::I64(-7),
            KeyVal::Bool(true),
            KeyVal::Str("hello".into()),
        ];
        let mut buf = Vec::new();
        encode_key_row(&row, &mut buf);
        encode_key_row(&row, &mut buf);
        let mut pos = 0;
        assert_eq!(decode_key_row(3, &buf, &mut pos).unwrap(), row);
        assert_eq!(decode_key_row(3, &buf, &mut pos).unwrap(), row);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn decode_truncated_errors_not_panics() {
        let row = vec![KeyVal::I64(42), KeyVal::Str("abcdef".into())];
        let mut buf = Vec::new();
        encode_key_row(&row, &mut buf);
        // every strict prefix must produce Err, never a panic
        for cut in 0..buf.len() {
            let mut pos = 0;
            assert!(
                decode_key_row(2, &buf[..cut], &mut pos).is_err(),
                "cut={cut}"
            );
        }
        // asking for more cells than encoded also errors
        let mut pos = 0;
        assert!(decode_key_row(3, &buf, &mut pos).is_err());
    }

    #[test]
    fn key_columns_rebuild() {
        let a = Column::I64(vec![4, 2]);
        let b = Column::Str(vec!["p".into(), "q".into()]);
        let rows = key_rows(&[&a, &b]).unwrap();
        let cols = key_columns(&rows, &[&a, &b]);
        assert_eq!(cols[0], a);
        assert_eq!(cols[1], b);
    }

    #[test]
    fn value_conversion() {
        assert_eq!(
            KeyVal::from_value(&Value::I64(3)).unwrap().to_value(),
            Value::I64(3)
        );
        assert!(KeyVal::from_value(&Value::F64(1.0)).is_err());
    }
}
