//! Out-of-core execution substrate: per-rank memory budgets and disk spill.
//!
//! HiFrames' operators materialize their inputs in RAM, which caps the
//! largest serviceable dataset at cluster memory. This module is the
//! foundation that lifts that ceiling (ROADMAP "out-of-core execution"):
//!
//! * [`MemoryBudget`] — a per-rank byte budget (configured through
//!   `HIFRAMES_MEM_BUDGET` / [`crate::config::mem_budget_from_env`]),
//!   tracked against [`Column::byte_size`] + validity-mask bytes.
//! * [`SpillFile`] — an on-disk sequence of u64-length-framed chunks, each
//!   chunk holding one `column/codec.rs` nullable encoding per column (the
//!   same wire format the shuffle and HFS use, so null positions survive
//!   the disk roundtrip bit-exactly).
//! * [`PartitionStore`] — hash-partitions a set of columns into `P` spill
//!   files using a level-salted finalizer mix ([`part_of`]) that is
//!   *independent* of the shuffle's `hash % nranks` routing (post-shuffle,
//!   all local rows agree mod `nranks`, so partitioning by the raw hash
//!   modulus would put everything in one bucket).
//! * [`SpillCtx`] — per-rank operator context owning the lazily created
//!   spill directory; dropping it (success *or* error path) deletes the
//!   files. Directories embed pid + rank so concurrent runs never collide,
//!   and a once-per-process sweep removes droppings of dead processes.
//!
//! The grace hash join ([`super::join`]), the two-phase spillable
//! aggregation ([`super::aggregate`]) and the external merge sort
//! ([`super::sort`]) all sit on these primitives; see DESIGN.md §4.5 for
//! the byte-identity arguments.

use super::join::MaskedCol;
use crate::column::{
    decode_nullable_column, encode_nullable_column_take, extend_opt_mask, Column, ValidityMask,
};
use crate::metrics::spill_stats;
use crate::trace::SpillScope;
use crate::types::DType;
use anyhow::{Context, Result};
use std::cell::{Cell, RefCell};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Once;

/// Recursion cap for grace-join / aggregation re-partitioning. Each level
/// re-salts the partition hash, so hitting the cap means the data is
/// pathologically duplicate-heavy; operators then process the partition
/// in memory rather than recursing forever.
pub const MAX_SPILL_DEPTH: u32 = 4;

/// Most partitions a single spill pass will fan out to.
const MAX_FANOUT: usize = 32;

/// Rows per framed chunk inside a spill file — bounds decode working-set
/// size for the streaming readers (k-way merge reads one chunk per run).
pub(crate) const SPILL_CHUNK_ROWS: usize = 8192;

// ---------------------------------------------------------------------------
// Budget
// ---------------------------------------------------------------------------

/// Per-rank memory budget in bytes. `None` = unlimited (today's in-memory
/// behavior, bit for bit).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryBudget {
    limit: Option<usize>,
}

impl MemoryBudget {
    pub fn unlimited() -> MemoryBudget {
        MemoryBudget { limit: None }
    }

    /// A budget of `n` bytes; `0` means unlimited (mirrors the env knob,
    /// where `HIFRAMES_MEM_BUDGET=0` disables budgeting).
    pub fn bytes(n: usize) -> MemoryBudget {
        MemoryBudget {
            limit: if n == 0 { None } else { Some(n) },
        }
    }

    pub fn from_opt(n: Option<usize>) -> MemoryBudget {
        MemoryBudget {
            limit: n.filter(|&n| n > 0),
        }
    }

    pub fn limit(&self) -> Option<usize> {
        self.limit
    }

    pub fn is_limited(&self) -> bool {
        self.limit.is_some()
    }

    /// Does holding `bytes` in memory exceed the budget?
    pub fn exceeded_by(&self, bytes: usize) -> bool {
        self.limit.map_or(false, |l| bytes > l)
    }

    /// Partition fan-out for spilling `total_bytes`: enough partitions that
    /// each is expected to fit in budget, at least 2 (a 1-way "partition"
    /// makes no progress), capped so tiny budgets don't open thousands of
    /// files.
    pub fn partition_count(&self, total_bytes: usize) -> usize {
        match self.limit {
            None => 1,
            Some(l) => total_bytes.div_ceil(l.max(1)).clamp(2, MAX_FANOUT),
        }
    }
}

/// Budget-relevant bytes of a masked column set: values + validity words.
pub fn masked_bytes(cols: &[MaskedCol]) -> usize {
    cols.iter()
        .map(|&(c, m)| c.byte_size() + m.map_or(0, |m| m.byte_size()))
        .sum()
}

/// Budget-relevant bytes of owned columns + optional masks.
pub fn nullable_bytes(cols: &[Column], masks: &[Option<ValidityMask>]) -> usize {
    cols.iter().map(|c| c.byte_size()).sum::<usize>()
        + masks
            .iter()
            .map(|m| m.as_ref().map_or(0, |m| m.byte_size()))
            .sum::<usize>()
}

// ---------------------------------------------------------------------------
// Partition hash
// ---------------------------------------------------------------------------

/// Spill partition of a key hash: a level-salted 64-bit finalizer mix
/// (murmur3 fmix64) over the row hash, reduced mod `nparts`.
///
/// Two properties matter:
/// * **independent of rank routing** — after a shuffle every local row
///   satisfies `hash % nranks == rank`, so the raw modulus would collapse
///   all rows into one bucket; the full-avalanche mix decorrelates the
///   partition index from the low bits.
/// * **level-salted** — recursive re-partitioning at `level + 1` splits a
///   partition along fresh boundaries; without the salt every row of a
///   partition would rehash into the same child forever.
pub fn part_of(hash: u64, nparts: usize, level: u32) -> usize {
    let mut x = hash ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(level as u64 + 1);
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    (x % nparts.max(1) as u64) as usize
}

// ---------------------------------------------------------------------------
// Spill directories (hygiene)
// ---------------------------------------------------------------------------

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn spill_root() -> PathBuf {
    std::env::temp_dir().join("hiframes-spill")
}

/// Remove spill directories left behind by processes that no longer exist.
/// Runs once per process, the first time any rank creates a spill dir.
pub fn sweep_stale_spill_dirs() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let Ok(entries) = std::fs::read_dir(spill_root()) else {
            return;
        };
        for e in entries.flatten() {
            let name = e.file_name();
            let Some(pid) = name
                .to_str()
                .and_then(|s| s.strip_prefix("pid"))
                .and_then(|s| s.parse::<u32>().ok())
            else {
                continue;
            };
            if pid != std::process::id() && !pid_alive(pid) {
                let _ = std::fs::remove_dir_all(e.path());
            }
        }
    });
}

#[cfg(target_os = "linux")]
fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

/// Off Linux there is no portable liveness probe in std; never sweep other
/// processes' directories (our own are covered by `Drop`).
#[cfg(not(target_os = "linux"))]
fn pid_alive(_pid: u32) -> bool {
    true
}

/// A per-rank spill directory: `$TMPDIR/hiframes-spill/pid<pid>/rank<r>-<n>`.
/// The pid segment keeps concurrent runs apart; the sequence number keeps
/// concurrent operators of one run apart. Dropped ⇒ recursively deleted.
struct SpillDir {
    path: PathBuf,
}

impl SpillDir {
    fn create(rank: usize) -> Result<SpillDir> {
        sweep_stale_spill_dirs();
        let seq = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = spill_root()
            .join(format!("pid{}", std::process::id()))
            .join(format!("rank{rank}-{seq}"));
        std::fs::create_dir_all(&path)
            .with_context(|| format!("spill: creating {}", path.display()))?;
        Ok(SpillDir { path })
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

// ---------------------------------------------------------------------------
// Spill files
// ---------------------------------------------------------------------------

/// One on-disk spill file: a sequence of `u64 payload_len` + payload
/// frames (HFS-style chunked layout). Frame payloads are produced by the
/// nullable column codec, so masks roundtrip with their columns. The file
/// is deleted on drop.
pub struct SpillFile {
    path: PathBuf,
    writer: Option<BufWriter<File>>,
    rows: usize,
    bytes: u64,
}

impl SpillFile {
    fn create(path: PathBuf) -> Result<SpillFile> {
        let f = File::create(&path)
            .with_context(|| format!("spill: creating {}", path.display()))?;
        Ok(SpillFile {
            path,
            writer: Some(BufWriter::new(f)),
            rows: 0,
            bytes: 0,
        })
    }

    /// Rows written so far (caller-reported per frame).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Bytes written so far, framing included.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Append one framed chunk covering `rows` rows.
    pub fn write_frame(&mut self, rows: usize, payload: &[u8]) -> Result<()> {
        let w = self
            .writer
            .as_mut()
            .context("spill: write after finish")?;
        w.write_all(&(payload.len() as u64).to_le_bytes())?;
        w.write_all(payload)?;
        self.rows += rows;
        self.bytes += 8 + payload.len() as u64;
        Ok(())
    }

    /// Flush and close the write side.
    pub fn finish(&mut self) -> Result<()> {
        if let Some(mut w) = self.writer.take() {
            w.flush().context("spill: flush")?;
        }
        Ok(())
    }

    /// Open a streaming reader over the frames (closes the writer first).
    pub fn reader(&mut self) -> Result<FrameReader> {
        self.finish()?;
        let f = File::open(&self.path)
            .with_context(|| format!("spill: reopening {}", self.path.display()))?;
        Ok(FrameReader {
            inner: BufReader::new(f),
        })
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        self.writer = None;
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Streaming frame iterator over a [`SpillFile`].
pub struct FrameReader {
    inner: BufReader<File>,
}

impl FrameReader {
    /// Next frame payload, or `None` at end of file.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>> {
        let mut len = [0u8; 8];
        match self.inner.read_exact(&mut len) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e).context("spill: reading frame length"),
        }
        let n = u64::from_le_bytes(len) as usize;
        let mut buf = vec![0u8; n];
        self.inner
            .read_exact(&mut buf)
            .context("spill: truncated frame payload")?;
        Ok(Some(buf))
    }
}

// ---------------------------------------------------------------------------
// Operator context
// ---------------------------------------------------------------------------

/// Per-rank, per-operator spill context: the budget plus a lazily created
/// [`SpillDir`]. Each rank builds its own (it is deliberately `!Sync`);
/// dropping it — normally or on an operator error path — removes every
/// spill file it handed out.
pub struct SpillCtx {
    budget: MemoryBudget,
    rank: usize,
    dir: RefCell<Option<SpillDir>>,
    seq: Cell<u64>,
    /// Per-node profiling sink (`None` on the unprofiled path). Spill
    /// counters always reach the process-global [`spill_stats`] sink; when
    /// a scope is attached they are *also* attributed to the plan node the
    /// executor is currently running. See DESIGN.md §4.7.
    scope: Option<Rc<SpillScope>>,
}

impl SpillCtx {
    pub fn new(budget: MemoryBudget, rank: usize) -> SpillCtx {
        SpillCtx {
            budget,
            rank,
            dir: RefCell::new(None),
            seq: Cell::new(0),
            scope: None,
        }
    }

    /// Attach a per-node profiling scope (builder-style).
    pub fn with_scope(mut self, scope: Option<Rc<SpillScope>>) -> SpillCtx {
        self.scope = scope;
        self
    }

    /// Record one spill pass that wrote `partitions` non-empty partitions
    /// totalling `bytes` on disk — into the global sink and, when
    /// profiling, the attached per-node scope.
    pub fn record_spill_pass(&self, partitions: u64, bytes: u64) {
        spill_stats().record_spill_pass(partitions, bytes);
        if let Some(scope) = &self.scope {
            scope.record_spill_pass(partitions, bytes);
        }
    }

    /// Record one merge/rehydration pass over spilled data.
    pub fn record_merge_pass(&self) {
        spill_stats().record_merge_pass();
        if let Some(scope) = &self.scope {
            scope.record_merge_pass();
        }
    }

    /// The no-op context: never spills; operators take the in-memory path.
    pub fn unlimited() -> SpillCtx {
        SpillCtx::new(MemoryBudget::unlimited(), 0)
    }

    pub fn budget(&self) -> MemoryBudget {
        self.budget
    }

    /// Should an operator holding `bytes` spill?
    pub fn should_spill(&self, bytes: usize) -> bool {
        self.budget.exceeded_by(bytes)
    }

    /// Create a fresh spill file (creating the per-rank directory on first
    /// use). `tag` is a human-readable label embedded in the file name.
    pub fn new_file(&self, tag: &str) -> Result<SpillFile> {
        let mut dir = self.dir.borrow_mut();
        if dir.is_none() {
            *dir = Some(SpillDir::create(self.rank)?);
        }
        let seq = self.seq.get();
        self.seq.set(seq + 1);
        let path = dir
            .as_ref()
            .unwrap()
            .path
            .join(format!("{seq:04}-{tag}.spill"));
        SpillFile::create(path)
    }
}

// ---------------------------------------------------------------------------
// Partition store
// ---------------------------------------------------------------------------

/// A set of columns hash-partitioned onto disk: partition `p` holds the
/// rows whose [`part_of`] (at this store's level) equals `p`. Reading a
/// partition back yields the rows in their original relative order —
/// frames are written and concatenated in ascending row order, which the
/// operators' byte-identity reconstructions rely on.
pub struct PartitionStore {
    parts: Vec<SpillFile>,
    dtypes: Vec<DType>,
    level: u32,
}

impl PartitionStore {
    /// Hash-partition `cols` (all of equal length) into `nparts` spill
    /// files under `ctx`, routing row `i` by `part_of(hashes[i], nparts,
    /// level)`. Updates the spill counters through `ctx` (global sink +
    /// per-node profiling scope when attached).
    pub fn partition(
        ctx: &SpillCtx,
        tag: &str,
        nparts: usize,
        level: u32,
        hashes: &[u64],
        cols: &[MaskedCol],
    ) -> Result<PartitionStore> {
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nparts];
        for (i, &h) in hashes.iter().enumerate() {
            buckets[part_of(h, nparts, level)].push(i);
        }
        let mut parts = Vec::with_capacity(nparts);
        let mut buf = Vec::new();
        let mut spilled_bytes = 0u64;
        let mut spilled_parts = 0u64;
        for (p, bucket) in buckets.iter().enumerate() {
            let mut file = ctx.new_file(&format!("{tag}-l{level}-p{p}"))?;
            for chunk in bucket.chunks(SPILL_CHUNK_ROWS) {
                buf.clear();
                for &(c, m) in cols {
                    encode_nullable_column_take(c, m, chunk, &mut buf);
                }
                file.write_frame(chunk.len(), &buf)?;
            }
            file.finish()?;
            spilled_bytes += file.bytes();
            if file.rows() > 0 {
                spilled_parts += 1;
            }
            parts.push(file);
        }
        ctx.record_spill_pass(spilled_parts, spilled_bytes);
        Ok(PartitionStore {
            parts,
            dtypes: cols.iter().map(|&(c, _)| c.dtype()).collect(),
            level,
        })
    }

    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    pub fn level(&self) -> u32 {
        self.level
    }

    pub fn part_rows(&self, p: usize) -> usize {
        self.parts[p].rows()
    }

    /// In-memory byte estimate of partition `p` (its on-disk size is the
    /// codec encoding, a close proxy for the decoded column bytes).
    pub fn part_bytes(&self, p: usize) -> usize {
        self.parts[p].bytes() as usize
    }

    /// Read partition `p` back into memory, concatenating frames in write
    /// order. Empty partitions come back as typed empty columns.
    pub fn read_part(&mut self, p: usize) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>)> {
        let ncols = self.dtypes.len();
        let mut cols: Vec<Column> = self
            .dtypes
            .iter()
            .map(|&dt| Column::new_empty(dt))
            .collect();
        let mut masks: Vec<Option<ValidityMask>> = vec![None; ncols];
        let mut reader = self.parts[p].reader()?;
        while let Some(frame) = reader.next_frame()? {
            let mut pos = 0;
            for k in 0..ncols {
                let (c, m) = decode_nullable_column(&frame, &mut pos)?;
                extend_opt_mask(&mut masks[k], cols[k].len(), m.as_ref(), c.len());
                cols[k].extend(&c);
            }
        }
        Ok((cols, masks))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_math() {
        let b = MemoryBudget::unlimited();
        assert!(!b.is_limited());
        assert!(!b.exceeded_by(usize::MAX));
        assert_eq!(b.partition_count(1 << 30), 1);

        let b = MemoryBudget::bytes(1000);
        assert!(b.is_limited());
        assert!(!b.exceeded_by(1000));
        assert!(b.exceeded_by(1001));
        assert_eq!(b.partition_count(1000), 2); // minimum useful fan-out
        assert_eq!(b.partition_count(4500), 5);
        assert_eq!(b.partition_count(usize::MAX), 32); // capped

        assert_eq!(MemoryBudget::bytes(0), MemoryBudget::unlimited());
        assert_eq!(MemoryBudget::from_opt(Some(0)), MemoryBudget::unlimited());
        assert_eq!(MemoryBudget::from_opt(Some(7)).limit(), Some(7));
        assert_eq!(MemoryBudget::from_opt(None).limit(), None);
    }

    #[test]
    fn part_of_decorrelates_rank_modulus() {
        // Post-shuffle pathology: every local hash agrees mod nranks.
        // part_of must still spread them across partitions.
        let nranks = 4u64;
        let hashes: Vec<u64> = (0..256u64).map(|i| i * nranks + 1).collect();
        let mut seen = [0usize; 8];
        for &h in &hashes {
            seen[part_of(h, 8, 0)] += 1;
        }
        assert!(
            seen.iter().all(|&c| c > 0),
            "partition histogram degenerate: {seen:?}"
        );
        // Level salt moves partition boundaries.
        assert!(
            hashes
                .iter()
                .any(|&h| part_of(h, 8, 0) != part_of(h, 8, 1)),
            "level salt had no effect"
        );
        // Deterministic.
        assert_eq!(part_of(42, 8, 3), part_of(42, 8, 3));
    }

    #[test]
    fn spill_file_roundtrip_and_cleanup() {
        let ctx = SpillCtx::new(MemoryBudget::bytes(1), 0);
        let mut f = ctx.new_file("t").unwrap();
        let path = f.path.clone();
        f.write_frame(2, b"ab").unwrap();
        f.write_frame(1, b"xyz").unwrap();
        assert_eq!(f.rows(), 3);
        assert_eq!(f.bytes(), 8 + 2 + 8 + 3);
        let mut r = f.reader().unwrap();
        assert_eq!(r.next_frame().unwrap().unwrap(), b"ab");
        assert_eq!(r.next_frame().unwrap().unwrap(), b"xyz");
        assert!(r.next_frame().unwrap().is_none());
        assert!(path.exists());
        drop(r);
        drop(f);
        assert!(!path.exists(), "spill file not deleted on drop");
    }

    #[test]
    fn partition_store_roundtrips_all_rows() {
        let ctx = SpillCtx::new(MemoryBudget::bytes(1), 0);
        let vals = Column::I64((0..100).collect());
        let mask = ValidityMask::from_bools(&(0..100).map(|i| i % 3 != 0).collect::<Vec<_>>());
        let names = Column::Str((0..100).map(|i| format!("s{i}")).collect());
        let hashes: Vec<u64> = (0..100u64).map(|i| i.wrapping_mul(0x9e3779b9)).collect();
        let cols: Vec<MaskedCol> = vec![(&vals, Some(&mask)), (&names, None)];
        let mut store = PartitionStore::partition(&ctx, "t", 4, 0, &hashes, &cols).unwrap();
        assert_eq!(store.num_parts(), 4);

        let mut got_rows = 0;
        let mut seen = vec![false; 100];
        for p in 0..4 {
            let (cols, masks) = store.read_part(p).unwrap();
            assert_eq!(cols.len(), 2);
            assert_eq!(cols[0].dtype(), DType::I64);
            assert_eq!(cols[1].dtype(), DType::Str);
            let ids = cols[0].as_i64();
            got_rows += ids.len();
            let mut last = None;
            for (j, &id) in ids.iter().enumerate() {
                let i = id as usize;
                assert!(!seen[i], "row {i} duplicated");
                seen[i] = true;
                // Relative order inside a partition is original row order.
                assert!(last.map_or(true, |l| l < i), "order broken in part {p}");
                last = Some(i);
                assert_eq!(part_of(hashes[i], 4, 0), p);
                assert_eq!(
                    masks[0].as_ref().map_or(true, |m| m.get(j)),
                    i % 3 != 0,
                    "mask wrong for row {i}"
                );
                assert_eq!(cols[1].as_str_col()[j], format!("s{i}"));
            }
        }
        assert_eq!(got_rows, 100);
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn scope_receives_spill_counters() {
        let scope = Rc::new(SpillScope::default());
        let ctx = SpillCtx::new(MemoryBudget::bytes(1), 0).with_scope(Some(scope.clone()));
        let vals = Column::I64((0..50).collect());
        let hashes: Vec<u64> = (0..50u64).map(|i| i.wrapping_mul(0x9e3779b9)).collect();
        let cols: Vec<MaskedCol> = vec![(&vals, None)];
        let _store = PartitionStore::partition(&ctx, "t", 4, 0, &hashes, &cols).unwrap();
        ctx.record_merge_pass();
        assert_eq!(scope.spill_passes.get(), 1);
        assert!(scope.bytes_spilled.get() > 0);
        assert!(scope.partitions_spilled.get() > 0);
        assert_eq!(scope.merge_passes.get(), 1);
    }

    #[test]
    fn empty_partition_is_typed() {
        let ctx = SpillCtx::new(MemoryBudget::bytes(1), 0);
        let vals = Column::F64(vec![]);
        let cols: Vec<MaskedCol> = vec![(&vals, None)];
        let mut store = PartitionStore::partition(&ctx, "t", 3, 1, &[], &cols).unwrap();
        for p in 0..3 {
            let (cols, masks) = store.read_part(p).unwrap();
            assert_eq!(cols[0].dtype(), DType::F64);
            assert_eq!(cols[0].len(), 0);
            assert!(masks[0].is_none());
        }
    }

    #[test]
    fn ctx_drop_removes_directory() {
        let ctx = SpillCtx::new(MemoryBudget::bytes(1), 7);
        let f = ctx.new_file("probe").unwrap();
        let dir = f.path.parent().unwrap().to_path_buf();
        assert!(dir.exists());
        let name = dir.file_name().unwrap().to_str().unwrap().to_string();
        assert!(name.starts_with("rank7-"), "dir name {name:?}");
        assert!(dir
            .parent()
            .unwrap()
            .file_name()
            .unwrap()
            .to_str()
            .unwrap()
            .starts_with("pid"));
        drop(f);
        drop(ctx);
        assert!(!dir.exists(), "spill dir not deleted on ctx drop");
    }

    #[test]
    fn stale_sweep_is_safe_to_call() {
        // The sweep runs at most once per process and must tolerate a
        // missing root; liveness-based removal is exercised implicitly.
        sweep_stale_spill_dirs();
        sweep_stale_spill_dirs();
    }
}
