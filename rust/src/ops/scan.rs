//! Cumulative sum (paper §4.5): "cumsum generates loops for local partial
//! sums and `MPI_Exscan` for the required parallel scan communication."
//! This is precisely the pattern map-reduce frameworks cannot express —
//! the Fig. 8b benchmark shows Spark SQL gathering everything onto one
//! executor instead.

use crate::comm::{Comm, ReduceOp};

/// Distributed cumulative sum over this rank's contiguous block of a
/// globally-ordered f64 column.
pub fn cumsum_f64(comm: &Comm, local: &[f64]) -> Vec<f64> {
    // local prefix sums
    let mut out = Vec::with_capacity(local.len());
    let mut acc = 0.0;
    for &x in local {
        acc += x;
        out.push(acc);
    }
    // exclusive scan of block totals, then shift
    let offset = comm.exscan_f64(acc, ReduceOp::Sum);
    if offset != 0.0 {
        for v in &mut out {
            *v += offset;
        }
    }
    out
}

/// Int64 variant.
pub fn cumsum_i64(comm: &Comm, local: &[i64]) -> Vec<i64> {
    let mut out = Vec::with_capacity(local.len());
    let mut acc = 0i64;
    for &x in local {
        acc += x;
        out.push(acc);
    }
    let offset = comm.exscan_i64(acc, ReduceOp::Sum);
    if offset != 0 {
        for v in &mut out {
            *v += offset;
        }
    }
    out
}

/// Serial oracle.
pub fn cumsum_serial_f64(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0;
    for &x in xs {
        acc += x;
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{block_range, run_spmd};

    #[test]
    fn matches_serial_split() {
        let data: Vec<f64> = (0..37).map(|i| (i as f64) * 0.5 - 3.0).collect();
        let expect = cumsum_serial_f64(&data);
        for p in [1usize, 2, 3, 5] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(data.len(), p, c.rank());
                cumsum_f64(&c, &data[s..s + l])
            });
            let got: Vec<f64> = out.into_iter().flatten().collect();
            assert_eq!(got.len(), expect.len());
            for (g, e) in got.iter().zip(&expect) {
                assert!((g - e).abs() < 1e-9, "p={p}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn i64_matches() {
        let data: Vec<i64> = (0..20).map(|i| i % 5 - 2).collect();
        let out = run_spmd(4, |c| {
            let (s, l) = block_range(data.len(), 4, c.rank());
            cumsum_i64(&c, &data[s..s + l])
        });
        let got: Vec<i64> = out.into_iter().flatten().collect();
        let mut acc = 0;
        let expect: Vec<i64> = data
            .iter()
            .map(|&x| {
                acc += x;
                acc
            })
            .collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn uneven_blocks_including_empty() {
        // 3 elements on 4 ranks: last rank holds nothing
        let data = vec![1.0, 2.0, 3.0];
        let out = run_spmd(4, |c| {
            let (s, l) = block_range(data.len(), 4, c.rank());
            cumsum_f64(&c, &data[s..s + l])
        });
        let got: Vec<f64> = out.into_iter().flatten().collect();
        assert_eq!(got, vec![1.0, 3.0, 6.0]);
    }
}
