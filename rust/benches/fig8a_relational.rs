//! Fig. 8a — basic relational operations: filter / join / aggregate on
//! serial (Pandas/Julia stand-in), sparklike (Spark SQL stand-in) and
//! HiFrames.
//!
//! Paper sizes: filter 2B rows, join 0.5M rows, aggregate 256M rows —
//! scaled by HIFRAMES_BENCH_SCALE (default 0.001 → 2M / 0.5M / 256K).
//! Expected shape (paper): HiFrames 3.8×/3.6×/70× vs Spark SQL and
//! 177×/21×/3.5× vs Pandas.

use hiframes::baseline::{serial, sparklike::SparkLike};
use hiframes::bench::*;
use hiframes::column::{
    decode_column, encode_column_with, set_dict_encoding, Column, DictEncoding,
};
use hiframes::datagen::{micro_table, skewed_table};
use hiframes::exec::ExecOptions;
use hiframes::fxhash::FxHashMap;
use hiframes::ops::keys::{
    cmp_key_rows, group_packed, key_rows, owner_of_key, KeyRow, PackedKeys, SortKeys,
};
use hiframes::passes::PassOptions;
use hiframes::prelude::*;

fn main() {
    bench_main("fig8a", || {
        let scale = bench_scale().min(0.01);
        let workers = bench_workers();
        let reps = bench_reps();
        let filter_rows = ((2e9 * scale) as usize).clamp(10_000, 4_000_000);
        let join_rows = ((0.5e6 * (scale * 1000.0)) as usize).clamp(10_000, 500_000);
        let agg_rows = ((256e6 * scale) as usize).clamp(10_000, 2_000_000);

        let mut table = BenchTable::new(
            &format!(
                "Fig 8a: relational ops (filter {filter_rows} rows, join {join_rows}, \
                 aggregate {agg_rows}; {workers} workers)"
            ),
            "sparklike",
        );

        // ---------------- filter ----------------
        let t = micro_table(filter_rows, 1000, 1);
        let pred = col("x").lt(lit(0.5));
        table.run("serial", "filter", filter_rows, 1, reps, || {
            serial::filter(&t, &pred).unwrap().num_rows()
        });
        {
            let eng = SparkLike::new(workers, workers * 2);
            let rdd = eng.parallelize(&t);
            table.run("sparklike", "filter", filter_rows, 1, reps, || {
                eng.filter(&rdd, &pred).unwrap().num_rows()
            });
        }
        let hf = HiFrames::with_workers(workers);
        let df = hf.table("t", t.clone());
        table.run("hiframes", "filter", filter_rows, 1, reps, || {
            // count-style action: materialize the distributed result, no
            // driver gather (sparklike/serial cells also stop there)
            df.filter(pred.clone()).count().unwrap()
        });
        drop(df);
        drop(t);

        // ---------------- join ----------------
        let l = micro_table(join_rows, join_rows as i64 / 2, 2);
        let rt = micro_table(join_rows / 4, join_rows as i64 / 2, 3);
        let r = rt.project(&["id"]).unwrap();
        let r = Table::from_pairs(vec![("rid", r.column("id").unwrap().clone())]).unwrap();
        table.run("serial", "join", join_rows, 1, reps, || {
            serial::join(&l, &r, "id", "rid").unwrap().num_rows()
        });
        {
            let eng = SparkLike::new(workers, workers * 2);
            let (lr, rr) = (eng.parallelize(&l), eng.parallelize(&r));
            table.run("sparklike", "join", join_rows, 1, reps, || {
                eng.join(&lr, &rr, "id", "rid").unwrap().num_rows()
            });
        }
        let dfl = hf.table("l", l.clone());
        let dfr = hf.table("r", r.clone());
        table.run("hiframes", "join", join_rows, 1, reps, || {
            dfl.join(&dfr, "id", "rid").count().unwrap()
        });

        // ---------------- aggregate ----------------
        let t = micro_table(agg_rows, 10_000, 4);
        let aggs = vec![
            AggExpr::new("xc", AggFn::Sum, col("x").lt(lit(0.5))),
            AggExpr::new("ym", AggFn::Mean, col("y")),
        ];
        table.run("serial", "aggregate", agg_rows, 1, reps, || {
            serial::aggregate(&t, "id", &aggs).unwrap().num_rows()
        });
        {
            let eng = SparkLike::new(workers, workers * 2);
            let rdd = eng.parallelize(&t);
            table.run("sparklike", "aggregate", agg_rows, 1, reps, || {
                eng.aggregate(&rdd, "id", &aggs).unwrap().num_rows()
            });
        }
        let df = hf.table("t", t.clone());
        table.run("hiframes", "aggregate", agg_rows, 1, reps, || {
            df.aggregate("id", aggs.clone()).count().unwrap()
        });

        table.finish("fig8a");

        // ------------- key packing (packed vs. materialized) -------------
        // The packed composite-key fast path measured against the KeyRow
        // materialization it replaced: hash-routing and grouping over the
        // aggregate cell's key volume. "materialized" is the old inner loop
        // (one Vec<KeyVal> per row); "packed" is PackedKeys.
        let n = agg_rows;
        let ids: Vec<i64> = (0..n as i64).map(|i| i % 10_000).collect();
        let k1 = Column::I64(ids.clone());
        let k2 = Column::Bool(ids.iter().map(|&i| i % 3 == 0).collect());
        let p = workers.max(2);
        let mut kp = BenchTable::new(
            &format!("Fig 8a addendum: composite-key packing ({n} rows, {p}-way routing)"),
            "materialized",
        );
        kp.run("materialized", "route-i64", n, 1, reps, || {
            let rows = key_rows(&[&k1]).unwrap();
            let mut acc = 0usize;
            for r in &rows {
                acc += owner_of_key(r, p);
            }
            acc
        });
        kp.run("packed", "route-i64", n, 1, reps, || {
            let packed = PackedKeys::pack(&[&k1]).unwrap();
            let mut acc = 0usize;
            for i in 0..packed.len() {
                acc += packed.owner(i, p);
            }
            acc
        });
        kp.run("materialized", "route-multi", n, 1, reps, || {
            let rows = key_rows(&[&k1, &k2]).unwrap();
            let mut acc = 0usize;
            for r in &rows {
                acc += owner_of_key(r, p);
            }
            acc
        });
        kp.run("packed", "route-multi", n, 1, reps, || {
            let packed = PackedKeys::pack(&[&k1, &k2]).unwrap();
            let mut acc = 0usize;
            for i in 0..packed.len() {
                acc += packed.owner(i, p);
            }
            acc
        });
        kp.run("materialized", "group-multi", n, 1, reps, || {
            let rows = key_rows(&[&k1, &k2]).unwrap();
            let mut m: FxHashMap<KeyRow, u32> = FxHashMap::default();
            for r in rows {
                let next = m.len() as u32;
                m.entry(r).or_insert(next);
            }
            m.len()
        });
        kp.run("packed", "group-multi", n, 1, reps, || {
            group_packed(&PackedKeys::pack(&[&k1, &k2]).unwrap()).num_groups()
        });
        kp.finish("fig8a_keypack");

        // ------------- null-ratio micro-bench (validity masks) -------------
        // A left join whose right side covers only part of the key space,
        // followed by a null-skipping aggregate over the null-introduced
        // column: the whole nullable pipeline (flagged packed keys, masked
        // shuffle wire, null-skipping reductions) at 0% / 10% / 50% nulls.
        // 0% is the no-null baseline — it measures the overhead the
        // subsystem adds when no mask exists (should be ~zero: fully valid
        // columns stay mask-free end to end).
        let nrows = (join_rows / 2).max(5_000);
        let mut nulls = BenchTable::new(
            &format!("Fig 8a addendum: null-ratio join+aggregate ({nrows} rows, {workers} workers)"),
            "hiframes",
        );
        for (pct, ratio) in [(0usize, 0.0f64), (10, 0.1), (50, 0.5)] {
            let ids: Vec<i64> = (0..nrows as i64).collect();
            let l = Table::from_pairs(vec![
                ("id", Column::I64(ids.clone())),
                ("g", Column::I64(ids.iter().map(|i| i % 64).collect())),
            ])
            .unwrap();
            // right side skips `ratio` of the keys → that fraction of left
            // rows gets a null w after the left join
            let keep: Vec<i64> = ids
                .iter()
                .copied()
                .filter(|&i| (i as f64 / nrows as f64) >= ratio)
                .collect();
            let r = Table::from_pairs(vec![
                ("rid", Column::I64(keep.clone())),
                ("w", Column::I64(keep.iter().map(|&i| i * 3).collect())),
            ])
            .unwrap();
            let dfl = hf.table("l", l);
            let dfr = hf.table("r", r);
            nulls.run(
                "hiframes",
                &format!("join-agg-{pct}"),
                nrows,
                1,
                reps,
                || {
                    dfl.join_on(&dfr, &[("id", "rid")], JoinType::Left)
                        .group_by(&["g"])
                        .agg("n", AggFn::Count, col("w"))
                        .agg("s", AggFn::Sum, col("w"))
                        .agg("m", AggFn::Mean, col("w"))
                        .build()
                        .count()
                        .unwrap()
                },
            );
        }
        nulls.finish("fig8a_nulls");

        // ------------- skewed-join micro-bench (heavy-hitter broadcast) ----
        // Zipf(1.5) probe keys: under plain hash partitioning the hot keys
        // pile onto one rank (the Q05 imbalance, paper §5.1) and that rank's
        // local join dominates wall-clock; the skew-broadcast path keeps the
        // heavy probe rows local (already evenly block-distributed) and
        // replicates only the few heavy build rows. "hash" runs with the
        // skew planner disabled; "skew-broadcast" forces the path via an
        // explicit hint, sampling included in the measured time.
        let srows = join_rows;
        let skew_keys = 10_000usize;
        let l = skewed_table(srows, skew_keys, 1.5, 11);
        let r = Table::from_pairs(vec![
            ("rid", Column::I64((0..skew_keys as i64).collect())),
            (
                "w",
                Column::I64((0..skew_keys as i64).map(|k| k * 3).collect()),
            ),
        ])
        .unwrap();
        let p = workers.max(2);
        let hash_hf = HiFrames::new(ExecOptions {
            workers: p,
            passes: PassOptions {
                skew_join: false,
                ..PassOptions::default()
            },
            ..Default::default()
        });
        let skew_hf = HiFrames::with_workers(p);
        let lh = hash_hf.table("l", l.clone());
        let rh = hash_hf.table("r", r.clone());
        let lsk = skew_hf.table("l", l);
        let rsk = skew_hf.table("r", r);
        let mut sk = BenchTable::new(
            &format!(
                "Fig 8a addendum: Zipf(1.5) skewed join ({srows} rows, {skew_keys} keys, \
                 {p} workers)"
            ),
            "hash",
        );
        sk.run("hash", "zipf-join", srows, 1, reps, || {
            lh.join(&rh, "id", "rid").count().unwrap()
        });
        sk.run("skew-broadcast", "zipf-join", srows, 1, reps, || {
            lsk.join_with(&rsk)
                .on("id", "rid")
                .skew_hint(0.05)
                .build()
                .count()
                .unwrap()
        });
        sk.finish("fig8a_skew");

        // ------------- shared-subplan dedup micro-bench (diamond plan) -----
        // A diamond: one expensive aggregate arm consumed twice (as join
        // probe and, re-keyed, as build). With hash-consing on, the arm
        // materializes once per rank and the second consumer reads the memo;
        // with it off, the arena holds two copies of the arm and both
        // execute. A final instrumented run attaches the reuse counters to
        // BENCH_fig8a_dedup.json as proof the dedup engaged.
        let drows = agg_rows;
        let dt = micro_table(drows, 5_000, 5);
        let p = workers.max(2);
        let diamond = |hf: &HiFrames| {
            let a = hf
                .table("t", dt.clone())
                .group_by(&["id"])
                .agg("s", AggFn::Sum, col("x"))
                .agg("n", AggFn::Count, col("x"))
                .build();
            let b = a
                .rename("id", "rid")
                .rename("s", "s2")
                .select(&["rid", "s2"]);
            a.join_on(&b, &[("id", "rid")], JoinType::Inner)
        };
        let dedup_hf = HiFrames::with_workers(p);
        let nodedup_hf = HiFrames::new(ExecOptions {
            workers: p,
            passes: PassOptions {
                dedup_subplans: false,
                ..PassOptions::default()
            },
            ..Default::default()
        });
        let mut dd = BenchTable::new(
            &format!(
                "Fig 8a addendum: shared-subplan diamond ({drows} rows, {p} workers)"
            ),
            "no-dedup",
        );
        dd.run("no-dedup", "diamond", drows, 1, reps, || {
            diamond(&nodedup_hf).count().unwrap()
        });
        dd.run("dedup", "diamond", drows, 1, reps, || {
            diamond(&dedup_hf).count().unwrap()
        });
        let df = diamond(&dedup_hf);
        let (_, stats) =
            hiframes::exec::collect_stats(df.plan().clone(), dedup_hf.options()).unwrap();
        dd.add_counter("nodes_executed", stats.nodes_executed);
        dd.add_counter("subplans_reused", stats.reuse_hits);
        dd.finish("fig8a_dedup");

        // ------------- radix argsort micro-bench (vectorized kernel floor) --
        // The LSD radix argsort measured against the stable comparison
        // argsort it replaced, over the packed order-preserving SortKeys
        // rows of the local sample-sort phase. "comparison" is the old path
        // — still callable, the in-bench fallback — and "radix" the new
        // kernel (forced, bypassing the width/row-count dispatch so the two
        // cells measure exactly one kernel each).
        let n = agg_rows.min(1_000_000);
        let ids: Vec<i64> = (0..n as i64).map(|i| i.wrapping_mul(0x9E37) % 100_000).collect();
        let k1 = Column::I64(ids.clone());
        let k2 = Column::Bool(ids.iter().map(|&i| i % 3 == 0).collect());
        let orders = [SortOrder::Asc, SortOrder::Desc];
        let sk1 = SortKeys::pack(&[&k1], &orders[..1]).unwrap().unwrap();
        let sk2 = SortKeys::pack(&[&k1, &k2], &orders).unwrap().unwrap();
        let mut rx = BenchTable::new(
            &format!("Fig 8a addendum: radix vs comparison argsort ({n} rows)"),
            "comparison",
        );
        rx.run("comparison", "argsort-i64", n, 1, reps, || {
            sk1.comparison_argsort().len()
        });
        rx.run("radix", "argsort-i64", n, 1, reps, || sk1.radix_argsort().len());
        rx.run("comparison", "argsort-multi", n, 1, reps, || {
            sk2.comparison_argsort().len()
        });
        rx.run("radix", "argsort-multi", n, 1, reps, || sk2.radix_argsort().len());
        // dictionary-coded string sort keys vs the KeyRow comparison sort
        // they replaced in the window/local-sort paths
        let sn = (n / 4).max(10_000);
        let strs = Column::Str((0..sn).map(|i| format!("key-{}", i % 997)).collect());
        let krows = key_rows(&[&strs]).unwrap();
        let sorders = [SortOrder::Asc];
        rx.run("comparison", "argsort-str", sn, 1, reps, || {
            let mut idx: Vec<usize> = (0..krows.len()).collect();
            idx.sort_by(|&a, &b| cmp_key_rows(&krows[a], &krows[b], &sorders));
            idx.len()
        });
        rx.run("radix", "argsort-str", sn, 1, reps, || {
            SortKeys::from_key_rows(&krows, &sorders).argsort().len()
        });
        rx.finish("fig8a_radix");

        // ------------- dictionary wire micro-bench (string shuffle frames) --
        // Plain escaped string frames vs dictionary frames on a
        // duplicate-heavy column — the wire every string shuffle and spill
        // ships. The explicit-mode encoder is the in-bench fallback toggle:
        // "plain" forces Off, "dict" forces the dictionary frame (Auto picks
        // by size at runtime and would choose "dict" here).
        let dn = agg_rows.min(1_000_000);
        let sv = Column::Str((0..dn).map(|i| format!("city-{:04}", i % 500)).collect());
        let mut plain_frame = Vec::new();
        encode_column_with(&sv, DictEncoding::Off, &mut plain_frame);
        let mut dict_frame = Vec::new();
        encode_column_with(&sv, DictEncoding::Force, &mut dict_frame);
        let mut dc = BenchTable::new(
            &format!("Fig 8a addendum: string wire encoding ({dn} rows, 500 distinct)"),
            "plain",
        );
        dc.run("plain", "encode", dn, 1, reps, || {
            let mut buf = Vec::new();
            encode_column_with(&sv, DictEncoding::Off, &mut buf);
            buf.len()
        });
        dc.run("dict", "encode", dn, 1, reps, || {
            let mut buf = Vec::new();
            encode_column_with(&sv, DictEncoding::Force, &mut buf);
            buf.len()
        });
        dc.run("plain", "decode", dn, 1, reps, || {
            let mut pos = 0;
            decode_column(&plain_frame, &mut pos).unwrap().len()
        });
        dc.run("dict", "decode", dn, 1, reps, || {
            let mut pos = 0;
            decode_column(&dict_frame, &mut pos).unwrap().len()
        });
        // end-to-end: a string-keyed distributed join with the dictionary
        // wire off vs on (the toggle is process-global; the bench harness
        // is single-threaded so this cannot race)
        let jrows = (join_rows / 2).max(5_000);
        let jl = Table::from_pairs(vec![
            (
                "k",
                Column::Str((0..jrows).map(|i| format!("key-{}", i % 2_000)).collect()),
            ),
            ("v", Column::I64((0..jrows as i64).collect())),
        ])
        .unwrap();
        let jr = Table::from_pairs(vec![
            (
                "rk",
                Column::Str((0..2_000).map(|i| format!("key-{i}")).collect()),
            ),
            ("w", Column::I64((0..2_000i64).collect())),
        ])
        .unwrap();
        let djl = hf.table("l", jl);
        let djr = hf.table("r", jr);
        dc.run("plain", "str-join", jrows, 1, reps, || {
            set_dict_encoding(DictEncoding::Off);
            djl.join_on(&djr, &[("k", "rk")], JoinType::Inner)
                .count()
                .unwrap()
        });
        dc.run("dict", "str-join", jrows, 1, reps, || {
            set_dict_encoding(DictEncoding::Force);
            djl.join_on(&djr, &[("k", "rk")], JoinType::Inner)
                .count()
                .unwrap()
        });
        set_dict_encoding(DictEncoding::Auto);
        dc.add_counter("plain_frame_bytes", plain_frame.len() as u64);
        dc.add_counter("dict_frame_bytes", dict_frame.len() as u64);
        dc.finish("fig8a_dict");
    });
}
