//! Fig. 13 — incremental standing query (DESIGN.md §4.9) vs per-tick full
//! recompute, on the BigBench Q01 dashboard shape: web sales arrive in
//! micro-batches and every tick re-answers "top spenders per category".
//!
//! Two systems, same ticks:
//! * `incremental` — one [`hiframes::stream::Session`]: push + `tick()`,
//!   per-tick wall clock straight from the tick reports;
//! * `recompute` — a cold `collect()` over the accumulated prefix after
//!   every tick (what an engine without operator state has to do).
//!
//! Per-tick rows processed / avoided land in the results JSON as counters;
//! the tick size honours `HIFRAMES_TICK_ROWS` (default: ~16 ticks).

use hiframes::bench::*;
use hiframes::bigbench::{self, q01};
use hiframes::exec::ExecOptions;
use hiframes::frame::HiFrames;
use hiframes::ops::aggregate::AggStrategy;
use hiframes::passes::PassOptions;
use std::time::Instant;

fn main() {
    bench_main("fig13_incremental", || {
        let workers = bench_workers();
        let sf = (bench_scale() * 1000.0).max(0.05);
        let db = bigbench::generate(&bigbench::GenOptions {
            scale_factor: sf,
            click_skew: 0.0,
            seed: 42,
        });
        let total = db.web_sales.num_rows();
        let tick_rows = hiframes::config::tick_rows_from_env()
            .expect("HIFRAMES_TICK_ROWS")
            .unwrap_or_else(|| (total / 16).max(1));
        let n_ticks = total.div_ceil(tick_rows);
        // the session forces these knobs; the recompute arm must match so
        // both run the same physical plan
        let hf = HiFrames::new(ExecOptions {
            workers,
            agg_strategy: AggStrategy::RawShuffle,
            mem_budget: None,
            profile: false,
            passes: PassOptions {
                skew_join: false,
                ..Default::default()
            },
        });
        let mut table = BenchTable::new(
            &format!(
                "Fig 13: Q01 standing query, {total} rows in {n_ticks} ticks \
                 of {tick_rows} ({workers} workers)"
            ),
            "recompute",
        );

        // incremental: one session across all ticks
        let mut session = q01::standing_session(&hf, &db).unwrap();
        let mut start = 0usize;
        let mut ticked = None;
        while start < total {
            let len = tick_rows.min(total - start);
            session
                .push("web_sales", db.web_sales.slice(start, len))
                .unwrap();
            start += len;
            ticked = Some(session.tick().unwrap());
        }
        let reports = session.reports().to_vec();
        table.record(
            "incremental",
            "tick",
            total,
            reports.iter().map(|r| r.wall_secs).collect(),
        );
        let processed: u64 = reports.iter().map(|r| r.rows_processed).sum();
        let avoided: u64 = reports.iter().map(|r| r.rows_avoided).sum();
        table.add_counter("ticks", n_ticks as u64);
        table.add_counter("rows_processed", processed);
        table.add_counter("rows_avoided", avoided);

        // full recompute: cold collect over the accumulated prefix
        let mut samples = Vec::with_capacity(n_ticks);
        let mut end = 0usize;
        let mut cold = None;
        while end < total {
            end = (end + tick_rows).min(total);
            let mut pdb = db.clone();
            pdb.web_sales = db.web_sales.slice(0, end);
            let t0 = Instant::now();
            cold = Some(q01::hiframes_query(&hf, &pdb).collect().unwrap());
            samples.push(t0.elapsed().as_secs_f64());
        }
        table.record("recompute", "tick", total, samples);

        // both arms must answer identically, byte for byte
        let (ticked, cold) = (ticked.unwrap(), cold.unwrap());
        assert_eq!(ticked.num_rows(), cold.num_rows());
        for i in 0..ticked.num_cols() {
            assert_eq!(ticked.column_at(i), cold.column_at(i), "column {i}");
            assert_eq!(ticked.mask_at(i), cold.mask_at(i), "mask {i}");
        }
        // the deterministic half of the claim: operator state means later
        // ticks never re-touch absorbed history
        assert!(avoided > 0, "no rows avoided across {n_ticks} ticks");

        table.finish("fig13_incremental");
    });
}
