//! Fig. 8b — advanced analytics: cumsum / SMA / WMA.
//!
//! Paper: 256M-row column; sparklike must gather everything to ONE executor
//! (map-reduce cannot scan/stencil), Pandas runs SMA vectorized but WMA
//! through a row lambda. Expected shape: HiFrames ≫ sparklike (1330–20356×
//! in the paper), Pandas SMA ≪ Pandas WMA.
//! Scaled by HIFRAMES_BENCH_SCALE (default 0.001 → 256K rows).

use hiframes::baseline::{serial, sparklike::SparkLike, sparklike::WindowKind};
use hiframes::bench::*;
use hiframes::ir::WindowAgg;
use hiframes::ops::stencil::{sma_weights, wma_weights_124};
use hiframes::prelude::*;
use std::sync::Arc;

fn main() {
    bench_main("fig8b", || {
        let scale = bench_scale().min(0.01);
        let workers = bench_workers();
        let reps = bench_reps();
        let rows = ((256e6 * scale) as usize).clamp(10_000, 4_000_000);

        let mut table = BenchTable::new(
            &format!("Fig 8b: analytics ops ({rows} rows, {workers} workers)"),
            "sparklike",
        );
        let t = Table::from_pairs(vec![("x", hiframes::datagen::series(rows, 7))]).unwrap();

        // ---------------- cumsum ----------------
        table.run("serial", "cumsum", rows, 1, reps, || {
            serial::cumsum(&t, "x", "cs").unwrap().num_rows()
        });
        {
            let eng = SparkLike::new(workers, workers * 2);
            let rdd = eng.parallelize(&t);
            table.run("sparklike", "cumsum", rows, 0, reps, || {
                eng.window_one_executor(&rdd, "x", "cs", WindowKind::Cumsum)
                    .unwrap()
                    .num_rows()
            });
        }
        let hf = HiFrames::with_workers(workers);
        let df = hf.table("t", t.clone());
        table.run("hiframes", "cumsum", rows, 1, reps, || {
            df.cumsum("x", "cs").count().unwrap()
        });

        // ---------------- SMA ----------------
        table.run("serial", "sma", rows, 1, reps, || {
            serial::sma(&t, "x", "s", 3).unwrap().num_rows()
        });
        {
            let eng = SparkLike::new(workers, workers * 2);
            let rdd = eng.parallelize(&t);
            table.run("sparklike", "sma", rows, 0, reps, || {
                eng.window_one_executor(&rdd, "x", "s", WindowKind::Stencil(sma_weights(3)))
                    .unwrap()
                    .num_rows()
            });
        }
        table.run("hiframes", "sma", rows, 1, reps, || {
            df.sma("x", "s", 3).count().unwrap()
        });

        // ---------------- WMA ----------------
        // serial WMA through a row lambda — the Pandas rolling.apply path
        table.run("serial-lambda", "wma", rows, 0, reps, || {
            serial::rolling_apply(&t, "x", "w", 3, &|win| {
                if win.len() == 3 {
                    (win[0] + 2.0 * win[1] + win[2]) / 4.0
                } else {
                    win.iter().sum::<f64>() / win.len() as f64
                }
            })
            .unwrap()
            .num_rows()
        });
        {
            let eng = SparkLike::new(workers, workers * 2);
            let rdd = eng.parallelize(&t);
            table.run("sparklike", "wma", rows, 0, reps, || {
                eng.window_one_executor(
                    &rdd,
                    "x",
                    "w",
                    WindowKind::StencilUdf {
                        window: 3,
                        func: Arc::new(|win: &[f64]| {
                            if win.len() == 3 {
                                (win[0] + 2.0 * win[1] + win[2]) / 4.0
                            } else {
                                win.iter().sum::<f64>() / win.len() as f64
                            }
                        }),
                    },
                )
                .unwrap()
                .num_rows()
            });
        }
        table.run("hiframes", "wma", rows, 1, reps, || {
            df.stencil("x", "w", wma_weights_124())
                .count()
                .unwrap()
        });

        // ---------------- partitioned WMA (hash window) ----------------
        // the same WMA per hash partition: HiFrames colocates each group
        // with the PackedKeys shuffle + per-group scan, the sparklike
        // engine pays the row shuffle + per-partition sort — the
        // "hash-vs-window" trajectory of the ranked/sessionized queries
        let groups = (rows / 4096).max(64);
        let tp = Table::from_pairs(vec![
            (
                "g",
                Column::I64((0..rows).map(|i| (i % groups) as i64).collect()),
            ),
            ("o", Column::I64((0..rows as i64).collect())),
            ("x", hiframes::datagen::series(rows, 7)),
        ])
        .unwrap();
        let aggs = vec![WindowAgg::new(
            "w",
            WindowFunc::Weighted(wma_weights_124()),
            WindowFrame::Rolling {
                preceding: 1,
                following: 1,
            },
            col("x"),
        )];
        table.run("serial", "pwma", rows, 1, reps, || {
            serial::window(&tp, &["g"], &[("o", SortOrder::Asc)], &aggs)
                .unwrap()
                .num_rows()
        });
        {
            let eng = SparkLike::new(workers, workers * 2);
            let rdd = eng.parallelize(&tp);
            table.run("sparklike", "pwma", rows, 0, reps, || {
                eng.window_over(&rdd, &["g"], &[("o", SortOrder::Asc)], &aggs)
                    .unwrap()
                    .num_rows()
            });
        }
        let dfp = hf.table("tp", tp.clone());
        table.run("hiframes", "pwma", rows, 1, reps, || {
            dfp.window()
                .partition_by(&["g"])
                .order_by(&[("o", SortOrder::Asc)])
                .rolling_between(1, 1)
                .agg("w", WindowFunc::Weighted(wma_weights_124()), col("x"))
                .build()
                .count()
                .unwrap()
        });

        table.finish("fig8b");
    });
}
