//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. **predicate pushdown through join** on/off (paper §4.3's flagship
//!    optimization, Fig. 6 workload shape);
//! 2. **1D_VAR lazy rebalance** vs rebalance-after-every-relational-op
//!    (paper §4.4: "this can be very costly");
//! 3. **local pre-aggregation** vs raw shuffle (the decomposed partial
//!    states of `expr::agg`);
//! 4. **column pruning** on/off over a wide source.

use hiframes::bench::*;
use hiframes::datagen::micro_table;
use hiframes::exec::{collect_optimized, ExecOptions};
use hiframes::ops::aggregate::AggStrategy;
use hiframes::passes::{optimize, PassOptions, RebalanceMode};
use hiframes::prelude::*;

fn main() {
    bench_main("ablations", || {
        let scale = bench_scale().min(0.01);
        let workers = bench_workers();
        let reps = bench_reps();
        let rows = ((500e6 * scale) as usize).clamp(50_000, 2_000_000);
        let mut table = BenchTable::new(
            &format!("Ablations ({rows} rows, {workers} workers)"),
            "off",
        );

        // ---- 1. predicate pushdown through join -----------------------------
        let hf = HiFrames::with_workers(workers);
        let customers = micro_table(rows / 10, rows as i64 / 10, 21);
        let orders = {
            let t = micro_table(rows, rows as i64 / 10, 22);
            Table::from_pairs(vec![
                ("customerId", t.column("id").unwrap().clone()),
                ("amount", t.column("y").unwrap().clone()),
            ])
            .unwrap()
        };
        let q = hf
            .table("customer", customers.clone())
            .join(&hf.table("order", orders.clone()), "id", "customerId")
            .filter(col("amount").gt(lit(90.0))); // selective predicate
        let plan = q.plan().clone();
        for (label, pushdown) in [("off", false), ("on", true)] {
            let passes = PassOptions {
                pushdown,
                ..PassOptions::default()
            };
            let optimized = optimize(plan.clone(), &passes).unwrap();
            let opts = ExecOptions {
                workers,
                passes,
                agg_strategy: AggStrategy::RawShuffle,
                mem_budget: None,
                profile: false,
            };
            table.run(label, "pushdown", rows, 1, reps, || {
                collect_optimized(&optimized, &opts).unwrap().num_rows()
            });
        }

        // ---- 2. lazy 1D_VAR vs always-rebalance ------------------------------
        let t = micro_table(rows, 1000, 23);
        let q = hf
            .table("t", t.clone())
            .filter(col("x").gt(lit(0.5)))
            .filter(col("y").gt(lit(10.0)))
            .sma("y", "s", 3);
        let plan = q.plan().clone();
        for (label, mode) in [("off", RebalanceMode::Always), ("on", RebalanceMode::Lazy)] {
            let passes = PassOptions {
                rebalance: mode,
                fuse_filters: false, // keep two relational ops for Always mode
                ..PassOptions::default()
            };
            let optimized = optimize(plan.clone(), &passes).unwrap();
            let nreb = hiframes::passes::distributed::count_rebalances(&optimized);
            eprintln!("  rebalance mode {mode:?}: {nreb} rebalance nodes");
            let opts = ExecOptions {
                workers,
                passes,
                agg_strategy: AggStrategy::RawShuffle,
                mem_budget: None,
                profile: false,
            };
            table.run(label, "lazy-1dvar", rows, 1, reps, || {
                collect_optimized(&optimized, &opts).unwrap().num_rows()
            });
        }

        // ---- 3. pre-aggregation vs raw shuffle -------------------------------
        // low-cardinality keys: pre-agg ships K states instead of N rows
        let t = micro_table(rows, 100, 24);
        let q = hf.table("t", t.clone()).aggregate(
            "id",
            vec![
                AggExpr::new("s", AggFn::Sum, col("x")),
                AggExpr::new("m", AggFn::Mean, col("y")),
            ],
        );
        let plan = optimize(q.plan().clone(), &PassOptions::default()).unwrap();
        for (label, strat) in [
            ("off", AggStrategy::RawShuffle),
            ("on", AggStrategy::PreAggregate),
        ] {
            let opts = ExecOptions {
                workers,
                passes: PassOptions::default(),
                agg_strategy: strat,
                mem_budget: None,
                profile: false,
            };
            table.run(label, "pre-agg", rows, 1, reps, || {
                collect_optimized(&plan, &opts).unwrap().num_rows()
            });
        }

        // ---- 4. column pruning over a wide source ----------------------------
        let wide = {
            let base = micro_table(rows, 1000, 25);
            let mut pairs: Vec<(String, Column)> = vec![
                ("id".into(), base.column("id").unwrap().clone()),
                ("x".into(), base.column("x").unwrap().clone()),
            ];
            for i in 0..10 {
                pairs.push((format!("pad{i}"), base.column("y").unwrap().clone()));
            }
            let refs: Vec<(&str, Column)> =
                pairs.iter().map(|(n, c)| (n.as_str(), c.clone())).collect();
            Table::from_pairs(refs).unwrap()
        };
        let q = hf
            .table("wide", wide.clone())
            .filter(col("x").lt(lit(0.5)))
            .select(&["id"]);
        let plan = q.plan().clone();
        for (label, prune) in [("off", false), ("on", true)] {
            let passes = PassOptions {
                prune_columns: prune,
                ..PassOptions::default()
            };
            let optimized = optimize(plan.clone(), &passes).unwrap();
            let opts = ExecOptions {
                workers,
                passes,
                agg_strategy: AggStrategy::RawShuffle,
                mem_budget: None,
                profile: false,
            };
            table.run(label, "pruning", rows, 1, reps, || {
                collect_optimized(&optimized, &opts).unwrap().num_rows()
            });
        }

        table.finish("ablations");
        for op in ["pushdown", "lazy-1dvar", "pre-agg", "pruning"] {
            if let (Some(off), Some(on)) = (table.median("off", op), table.median("on", op)) {
                println!("{op}: {:.2}x from the optimization", off / on);
            }
        }
    });
}
