//! Fig. 12 — strong scaling of Q26: worker sweep at a fixed scale factor.
//! Paper: HiFrames keeps scaling to 64 nodes while Spark SQL flattens and
//! regresses past 16 (master bottleneck); 5× at 64 nodes.
//!
//! This box has few cores — the sweep tops out at 2× the physical count
//! and the flattening point appears early; the *relative* shape (HiFrames
//! scales to the core count, sparklike stalls sooner) is the reproduced
//! claim.

use hiframes::baseline::sparklike::SparkLike;
use hiframes::bench::*;
use hiframes::bigbench::{self, q26};
use hiframes::frame::HiFrames;

fn main() {
    bench_main("fig12", || {
        let reps = bench_reps();
        let mult = (bench_scale() * 1000.0).max(0.1);
        let sf = 2.0 * mult;
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        let mut sweep = vec![1usize, 2, 4, 8];
        sweep.retain(|&w| w <= (cores * 2).max(2));

        let db = bigbench::generate(&bigbench::GenOptions {
            scale_factor: sf,
            click_skew: 0.0,
            seed: 42,
        });
        let rows = db.store_sales.num_rows();
        let p = q26::Q26Params::default();

        let mut table = BenchTable::new(
            &format!("Fig 12: Q26 strong scaling, sf={sf} ({rows} sales rows, {cores} cores)"),
            "sparklike",
        );
        for &w in &sweep {
            let hf = HiFrames::with_workers(w);
            table.run("hiframes", &format!("{w}w"), rows, 1, reps, || {
                q26::hiframes_relational(&hf, &db, &p).collect().unwrap().num_rows()
            });
            let eng = SparkLike::new(w, w * 2);
            table.run("sparklike", &format!("{w}w"), rows, 1, reps, || {
                eng.collect(&q26::sparklike_relational(&eng, &db, &p).unwrap())
                    .unwrap()
                    .num_rows()
            });
        }
        table.finish("fig12");
        // speedup-vs-1-worker series (the figure's y axis)
        for sys in ["hiframes", "sparklike"] {
            if let Some(base) = table.median(sys, "1w") {
                let series: Vec<String> = sweep
                    .iter()
                    .filter_map(|w| {
                        table
                            .median(sys, &format!("{w}w"))
                            .map(|m| format!("{w}w:{:.2}x", base / m))
                    })
                    .collect();
                println!("{sys} scaling: {}", series.join("  "));
            }
        }

        // ---- out-of-core smoke: the same query under a tight per-rank
        // memory budget (HIFRAMES_MEM_BUDGET, default 5% of the fact
        // table); the spill counters ride along in BENCH_fig12_spill.json
        // so CI tracks that the operators really went to disk ----
        let budget = hiframes::config::mem_budget_from_env()
            .unwrap_or_else(|| (db.store_sales.byte_size() / 20).max(1));
        let w = sweep.last().copied().unwrap_or(1);
        let hf = HiFrames::new(hiframes::exec::ExecOptions {
            workers: w,
            mem_budget: Some(budget),
            ..Default::default()
        });
        let mut spill_table = BenchTable::new(
            &format!("Fig 12 (spill): Q26 under a {budget}-byte per-rank budget, {w} workers"),
            "hiframes",
        );
        hiframes::metrics::spill_stats().reset();
        spill_table.run("hiframes", "q26-budgeted", rows, 1, reps, || {
            q26::hiframes_relational(&hf, &db, &p).collect().unwrap().num_rows()
        });
        let sp = hiframes::metrics::spill_stats().snapshot();
        spill_table.add_counter("mem_budget_bytes", budget as u64);
        spill_table.add_counter("bytes_spilled", sp.bytes_spilled);
        spill_table.add_counter("partitions_spilled", sp.partitions_spilled);
        spill_table.add_counter("spill_passes", sp.spill_passes);
        spill_table.add_counter("merge_passes", sp.merge_passes);
        spill_table.finish("fig12_spill");
    });
}
