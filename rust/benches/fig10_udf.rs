//! Fig. 9/10 — UDF overhead.
//!
//! The same filter+project query written (a) with built-in expressions and
//! (b) with a user-defined function. Paper: Spark SQL pays +24% (Python) /
//! +46% (Scala); HiFrames pays ~0% because UDFs compile into the same
//! vectorized kernels. Our sparklike engine pays the boxed-closure +
//! per-row-argument-buffer cost; HiFrames evaluates the UDF columnar.

use hiframes::baseline::sparklike::SparkLike;
use hiframes::bench::*;
use hiframes::datagen::micro_table;
use hiframes::prelude::*;

fn main() {
    bench_main("fig10", || {
        let scale = bench_scale().min(0.01);
        let workers = bench_workers();
        let reps = bench_reps();
        let rows = ((1e9 * scale) as usize).clamp(50_000, 2_000_000);

        let mut table = BenchTable::new(
            &format!("Fig 10: UDF overhead ({rows} rows, {workers} workers)"),
            "sparklike",
        );
        let t = micro_table(rows, 1000, 11);

        // the computation: keep rows with 2x + 1 < y, emit that value
        let builtin = col("x").mul(lit(2.0)).add(lit(1.0));
        let udf = Expr::Udf(
            Udf::new("affine", |a| a[0] * 2.0 + 1.0),
            vec![col("x")],
        );

        for (label, expr) in [("no-udf", &builtin), ("udf", &udf)] {
            let eng = SparkLike::new(workers, workers * 2);
            let rdd = eng.parallelize(&t);
            let pred = expr.clone().lt(col("y"));
            let e2 = expr.clone();
            table.run("sparklike", label, rows, 1, reps, || {
                let f = eng.filter(&rdd, &pred).unwrap();
                let w = eng.with_column(&f, "v", &e2).unwrap();
                w.num_rows()
            });
        }
        let hf = HiFrames::with_workers(workers);
        let df = hf.table("t", t.clone());
        for (label, expr) in [("no-udf", &builtin), ("udf", &udf)] {
            let pred = expr.clone().lt(col("y"));
            let e2 = expr.clone();
            table.run("hiframes", label, rows, 1, reps, || {
                df.filter(pred.clone())
                    .with_column("v", e2.clone())
                    .count()
                    .unwrap()
            });
        }
        table.finish("fig10");
        // overhead percentages, as the paper reports them
        for sys in ["sparklike", "hiframes"] {
            if let (Some(base), Some(with)) =
                (table.median(sys, "no-udf"), table.median(sys, "udf"))
            {
                println!("{sys}: UDF overhead {:+.1}%", (with / base - 1.0) * 100.0);
            }
        }
    });
}
