//! Fig. 11 — TPCx-BB Q26 / Q25 / Q05 across scale factors, HiFrames vs
//! sparklike. Paper shape: HiFrames 3–7× (Q26), 5–10× (Q25), and for Q05 a
//! skewed-join stress (we additionally report the hash-partition imbalance
//! factor the paper attributes Spark's OOM to).
//!
//! Scale factors swept: {0.5, 1, 2} × HIFRAMES_BENCH_SCALE×1000 (default 1).

use hiframes::baseline::sparklike::SparkLike;
use hiframes::bench::*;
use hiframes::bigbench::{self, q05, q25, q26};
use hiframes::frame::HiFrames;

fn main() {
    bench_main("fig11", || {
        let workers = bench_workers();
        let reps = bench_reps();
        let mult = (bench_scale() * 1000.0).max(0.1);
        let sfs: Vec<f64> = [0.5, 1.0, 2.0].iter().map(|s| s * mult).collect();

        let mut table = BenchTable::new(
            &format!("Fig 11: TPCx-BB queries, sf sweep {sfs:?} ({workers} workers)"),
            "sparklike",
        );

        for &sf in &sfs {
            let db = bigbench::generate(&bigbench::GenOptions {
                scale_factor: sf,
                click_skew: 0.0,
                seed: 42,
            });
            let rows = db.store_sales.num_rows();
            let hf = HiFrames::with_workers(workers);

            // Q26
            let p26 = q26::Q26Params::default();
            table.run("hiframes", &format!("q26/sf{sf}"), rows, 1, reps, || {
                q26::hiframes_relational(&hf, &db, &p26)
                    .collect()
                    .unwrap()
                    .num_rows()
            });
            {
                let eng = SparkLike::new(workers, workers * 2);
                table.run("sparklike", &format!("q26/sf{sf}"), rows, 1, reps, || {
                    eng.collect(&q26::sparklike_relational(&eng, &db, &p26).unwrap())
                        .unwrap()
                        .num_rows()
                });
            }

            // Q25
            table.run("hiframes", &format!("q25/sf{sf}"), rows, 1, reps, || {
                q25::hiframes_relational(&hf, &db).collect().unwrap().num_rows()
            });
            {
                let eng = SparkLike::new(workers, workers * 2);
                table.run("sparklike", &format!("q25/sf{sf}"), rows, 1, reps, || {
                    eng.collect(&q25::sparklike_relational(&eng, &db).unwrap())
                        .unwrap()
                        .num_rows()
                });
            }

            // Q05 (uniform keys)
            let clicks = db.web_clickstream.num_rows();
            table.run("hiframes", &format!("q05/sf{sf}"), clicks, 1, reps, || {
                q05::hiframes_relational(&hf, &db).collect().unwrap().num_rows()
            });
            {
                let eng = SparkLike::new(workers, workers * 2);
                table.run("sparklike", &format!("q05/sf{sf}"), clicks, 1, reps, || {
                    eng.collect(&q05::sparklike_relational(&eng, &db).unwrap())
                        .unwrap()
                        .num_rows()
                });
            }
        }
        // HIFRAMES_PROFILE=1: profile one Q26 run, fold the summary into the
        // results JSON and drop a Chrome trace next to it (CI smoke-checks
        // both — see `.github/workflows/ci.yml`).
        if hiframes::config::profile_from_env() {
            let db = bigbench::generate(&bigbench::GenOptions {
                scale_factor: sfs[0],
                click_skew: 0.0,
                seed: 42,
            });
            let hf = HiFrames::with_workers(workers);
            let (_, prof) = q26::hiframes_relational(&hf, &db, &q26::Q26Params::default())
                .collect_profiled()
                .unwrap();
            table.add_counter("profile_nodes_executed", prof.executed_nodes() as u64);
            table.add_counter("profile_elapsed_us", prof.elapsed_ns() / 1_000);
            table.add_counter("profile_shuffle_bytes", prof.total_bytes_shuffled());
            table.add_counter("profile_spill_bytes", prof.total_bytes_spilled());
            table.add_counter("profile_collectives", prof.total_collectives());
            table.add_counter(
                "profile_max_imbalance_x100",
                (prof.max_imbalance() * 100.0) as u64,
            );
            match prof.write_chrome_trace("fig11_q26") {
                Ok(path) => eprintln!("[fig11] Chrome trace written to {}", path.display()),
                Err(e) => eprintln!("[fig11] could not write Chrome trace: {e}"),
            }
        }
        table.finish("fig11");

        // Q05 skew experiment: imbalance factor under Zipf keys
        println!("\nQ05 skewed-join load imbalance (paper: Spark OOM > SF50):");
        for skew in [0.0, 1.0, 1.5] {
            let db = bigbench::generate(&bigbench::GenOptions {
                scale_factor: sfs[1],
                click_skew: skew,
                seed: 42,
            });
            let (factor, counts) = q05::join_imbalance(&db, workers.max(2)).unwrap();
            println!("  skew alpha={skew}: max/mean = {factor:5.2}  per-rank rows {counts:?}");
        }
    });
}
