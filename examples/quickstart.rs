//! Quickstart: the HiFrames data-frame API on a small table — every row of
//! the paper's Table 1 in one runnable program.
//!
//!     cargo run --release --example quickstart
//!
//! For the module map and the frame API → IR → passes → ops → exec → comm
//! data-flow walk, see ARCHITECTURE.md at the repository root (DESIGN.md
//! has the per-subsystem protocols).

use hiframes::prelude::*;

fn main() -> anyhow::Result<()> {
    // a small frame: integer key + two numeric columns (the paper's
    // micro-benchmark schema)
    let hf = HiFrames::with_workers(4);
    let df1 = hf.table(
        "df1",
        Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2, 3, 4, 5, 6, 7, 8])),
            (
                "x",
                Column::F64(vec![0.5, 1.5, 0.7, 2.5, 0.2, 3.5, 0.9, 1.1]),
            ),
            (
                "y",
                Column::F64(vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0]),
            ),
        ])?,
    );

    // ---- projection: v = df[:id] -----------------------------------------
    let ids = df1.select(&["id"]).collect()?;
    println!("projection:\n{ids}");

    // ---- filter: df2 = df[:id < 5] ----------------------------------------
    let df2 = df1.filter(col("id").lt(lit(5i64)));
    println!("filter id<5:\n{}", df2.collect()?);

    // ---- join: df3 = join(df1, dfr, :id == :cid) ---------------------------
    let dfr = hf.table(
        "dfr",
        Table::from_pairs(vec![
            ("cid", Column::I64(vec![2, 4, 6, 8])),
            ("label", Column::Str(vec!["a".into(), "b".into(), "c".into(), "d".into()])),
        ])?,
    );
    let df3 = df1.join(&dfr, "id", "cid").sort_by("id");
    println!("join:\n{}", df3.collect()?);

    // ---- aggregate: df2 = aggregate(df1, :id, :xc = sum(:x<1.0), :ym = mean(:y))
    let keyed = df1.with_column("id", col("id").rem(lit(3i64)));
    let agg = keyed
        .aggregate(
            "id",
            vec![
                AggExpr::new("xc", AggFn::Sum, col("x").lt(lit(1.0))),
                AggExpr::new("ym", AggFn::Mean, col("y")),
            ],
        )
        .sort_by("id");
    println!("aggregate:\n{}", agg.collect()?);

    // ---- concatenation: df3 = [df1; df2] -----------------------------------
    println!("concat rows: {}", df1.concat(&df1).count()?);

    // ---- cumulative sum ----------------------------------------------------
    let cs = df1.cumsum("x", "cumsum_x").select(&["cumsum_x"]);
    println!("cumsum:\n{}", cs.collect()?);

    // ---- SMA / WMA stencils (Table 1's stencil API) ------------------------
    let sma = df1.sma("x", "sma3", 3).select(&["sma3"]).collect()?;
    println!("SMA(3):\n{sma}");
    let wma = df1.wma("x", "wma").select(&["wma"]).collect()?;
    println!("WMA (x[-1]+2x[0]+x[1])/4:\n{wma}");

    // ---- general array expressions + UDF inside a filter -------------------
    let udf = Udf::new("norm", |a| (a[0] * a[0] + a[1] * a[1]).sqrt());
    let fancy = df1.filter(
        Expr::Udf(udf, vec![col("x"), col("y")]).lt(lit(50.0)),
    );
    println!("UDF filter rows: {}", fancy.count()?);

    // ---- composite-key relational API --------------------------------------
    // LEFT join against a sparse dimension: unmatched rows survive with
    // their native dtype — :score stays Int64, missing rows are NULL under
    // the column's validity mask (no Float64/NaN promotion)
    let sparse = hf.table(
        "sparse",
        Table::from_pairs(vec![
            ("sid", Column::I64(vec![1, 4, 7])),
            ("score", Column::I64(vec![100, 400, 700])),
        ])?,
    );
    let left = df1
        .join_on(&sparse, &[("id", "sid")], JoinType::Left)
        .sort_by("id");
    let left_t = left.collect()?;
    println!("left join (null = missing dimension row):\n{left_t}");
    println!(
        ":score kept dtype {} with {} nulls",
        left_t.schema().dtype_of("score").unwrap(),
        left_t.null_count("score"),
    );

    // ---- null handling: is_null / fill_null / drop_null --------------------
    // fill_null repairs the holes in place (column becomes non-nullable,
    // dtype unchanged) …
    let filled = left.fill_null("score", 0i64).sort_by("id").collect()?;
    println!("fill_null(score, 0):\n{filled}");
    // … drop_null keeps only rows with a real dimension entry …
    let dropped = left.drop_null(&["score"]).sort_by("id").collect()?;
    println!("drop_null([score]) rows: {}", dropped.num_rows());
    // … and is_null exposes the missingness itself as a Bool feature
    let probed = left.is_null("score").sort_by("id").collect()?;
    println!("is_null(score):\n{}", probed.project(&["id", "score_is_null"])?);

    // multi-key group-by via the fluent builder, then a multi-key ORDER BY
    // (count descending, key ascending)
    let grouped = df1
        .with_column("bucket", col("id").rem(lit(2i64)))
        .with_column("half", col("id").rem(lit(3i64)))
        .group_by(&["bucket", "half"])
        .agg("n", AggFn::Count, col("x"))
        .agg("sum_x", AggFn::Sum, col("x"))
        .build()
        .sort_by_keys(&[("n", SortOrder::Desc), ("bucket", SortOrder::Asc)]);
    println!("multi-key group-by + sort:\n{}", grouped.collect()?);

    // SEMI join: which rows have a matching dimension entry?
    let semi = df1.join_on(&sparse, &[("id", "sid")], JoinType::Semi);
    println!("semi join rows: {}", semi.count()?);

    // skew-aware join: force the heavy-hitter broadcast path with an
    // explicit frequency threshold (on large skewed sources the planner
    // selects it automatically — ARCHITECTURE.md / DESIGN.md §4.3)
    let skew_joined = df1
        .join_with(&sparse)
        .on("id", "sid")
        .how(JoinType::Left)
        .skew_hint(0.2)
        .build();
    println!("skew-hinted left join rows: {}", skew_joined.count()?);

    // the optimized plan for the join query, as the compiler sees it
    println!("\noptimized plan for the join query:");
    let optimized = hiframes::passes::optimize(
        df3.plan().clone(),
        &hiframes::passes::PassOptions::default(),
    )?;
    println!("{optimized}");
    Ok(())
}
