//! Advanced analytics (paper §3.1 & Fig. 8b): cumulative sums and moving
//! averages over a time series — the operations map-reduce systems cannot
//! express efficiently, compiled here to exscan + halo exchanges.
//!
//!     cargo run --release --example moving_averages

use hiframes::baseline::sparklike::{SparkLike, WindowKind};
use hiframes::metrics::time_it;
use hiframes::prelude::*;

fn main() -> anyhow::Result<()> {
    let n = 1_000_000;
    let workers = hiframes::config::default_workers();
    println!("series of {n} points, {workers} workers");

    let series = hiframes::datagen::series(n, 42);
    let t = Table::from_pairs(vec![("x", series)])?;

    let hf = HiFrames::with_workers(workers);
    let df = hf.table("ts", t.clone());

    // HiFrames: cumsum via MPI_Exscan-style scan
    let (cs, secs) = time_it(|| df.cumsum("x", "cs").collect().unwrap());
    println!(
        "hiframes cumsum    {:8.1} ms  (last={:.3})",
        secs * 1e3,
        cs.column("cs").unwrap().as_f64()[n - 1]
    );

    // HiFrames: SMA/WMA via halo-exchange stencils
    let (sma, secs) = time_it(|| df.sma("x", "sma", 5).collect().unwrap());
    println!(
        "hiframes SMA(5)    {:8.1} ms  (mid={:.3})",
        secs * 1e3,
        sma.column("sma").unwrap().as_f64()[n / 2]
    );
    let (_, secs) = time_it(|| df.wma("x", "wma").collect().unwrap());
    println!("hiframes WMA       {:8.1} ms", secs * 1e3);

    // sparklike: gathers everything onto one executor (the Fig. 8b failure
    // mode), on a slice so the demo stays quick
    let slice = t.slice(0, 200_000);
    let eng = SparkLike::new(workers, workers * 2);
    let rdd = eng.parallelize(&slice);
    let (_, secs) = time_it(|| {
        eng.window_one_executor(&rdd, "x", "cs", WindowKind::Cumsum)
            .unwrap()
    });
    println!("sparklike cumsum   {:8.1} ms  (on 200k rows — single-executor gather)", secs * 1e3);

    // serial pandas-like: vectorized SMA vs row-lambda WMA (the Pandas gap)
    let (_, secs) = time_it(|| {
        hiframes::baseline::serial::sma(&slice, "x", "sma", 5).unwrap()
    });
    println!("serial SMA (vectorized) {:6.1} ms (200k rows)", secs * 1e3);
    let (_, secs) = time_it(|| {
        hiframes::baseline::serial::rolling_apply(&slice, "x", "wma", 3, &|w| {
            let mid = w.len() / 2;
            (w[mid.saturating_sub(1)] + 2.0 * w[mid] + w[mid + 1.min(w.len() - 1 - mid)]) / 4.0
        })
        .unwrap()
    });
    println!("serial WMA (row lambda) {:6.1} ms (200k rows)", secs * 1e3);

    Ok(())
}
