//! END-TO-END driver: the paper's §3.2 customer-
//! segmentation program — TPCx-BB Q26 — through ALL THREE LAYERS:
//!
//!   L3 rust: data generation → HFS files → parallel hyperslab reads →
//!            optimized relational plan (pushdown, pruning, 1D_VAR) →
//!            SPMD join/aggregate/filter → feature scaling →
//!            matrix assembly (rebalance inserted automatically)
//!   L2/L1:   k-means via the AOT-compiled JAX model calling the Pallas
//!            distance kernel, executed from rust over PJRT
//!
//!     make artifacts && cargo run --release --example customer_segmentation

use hiframes::bigbench::{self, q26};
use hiframes::metrics::time_it;

fn main() -> anyhow::Result<()> {
    let workers = hiframes::config::default_workers();
    let sf = std::env::var("HIFRAMES_SF")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);
    println!("customer segmentation (TPCx-BB Q26): sf={sf} workers={workers}");

    // 1. generate and persist to HFS (the paper reads HDF5 files)
    let db = bigbench::generate(&bigbench::GenOptions {
        scale_factor: sf,
        click_skew: 0.0,
        seed: 7,
    });
    let dir = std::env::temp_dir().join("hiframes_e2e");
    std::fs::create_dir_all(&dir)?;
    let ss_path = dir.join("store_sales.hfs");
    let item_path = dir.join("item.hfs");
    hiframes::io::write_hfs(&ss_path, &db.store_sales)?;
    hiframes::io::write_hfs(&item_path, &db.item)?;
    println!(
        "wrote {} sales rows + {} items to {}",
        db.store_sales.num_rows(),
        db.item.num_rows(),
        dir.display()
    );

    // 2. the §3.2 program, reading from files
    let hf = HiFrames::with_workers(workers);
    let store_sales = hf.read_hfs("store_sales", &ss_path)?;
    let item = hf.read_hfs("item", &item_path)?;

    use hiframes::prelude::*;
    let p = q26::Q26Params::default();
    let books = item.filter(col("i_category").eq_(lit(p.category.as_str())));
    let sale_items = store_sales.join(&books, "ss_item_sk", "i_item_sk");
    let mut aggs = vec![AggExpr::new("cnt", AggFn::Count, col("i_class_id"))];
    for k in 1..=q26::N_FEATURES {
        aggs.push(AggExpr::new(
            &format!("id{k}"),
            AggFn::Sum,
            col("i_class_id").eq_(lit(k)),
        ));
    }
    let c_i_points = sale_items
        .aggregate("ss_customer_sk", aggs)
        .filter(col("cnt").gt(lit(p.min_count)));

    let ((m, v), secs_scalar) = time_it(|| {
        (
            c_i_points.mean("id3").unwrap(),
            c_i_points.var("id3").unwrap().max(1e-9),
        )
    });
    let scaled = c_i_points.with_column("id3", col("id3").sub(lit(m)).div(lit(v)));

    let (relational, secs_rel) = time_it(|| scaled.clone().collect().unwrap());
    println!(
        "relational stage: {} customers in {:.1} ms (+{:.1} ms scaling stats)",
        relational.num_rows(),
        secs_rel * 1e3,
        secs_scalar * 1e3
    );
    println!("  throughput: {:.2} M input rows/s",
        hiframes::metrics::mrows_per_sec(db.store_sales.num_rows(), secs_rel));

    // 3. k-means through PJRT artifacts (fallback to the rust kernel when
    //    artifacts are missing, so the example always runs)
    let use_pjrt = hiframes::runtime::artifacts_available();
    let feature_names: Vec<String> = std::iter::once("cnt".to_string())
        .chain((1..=q26::N_FEATURES).map(|k| format!("id{k}")))
        .collect();
    let refs: Vec<&str> = feature_names.iter().map(|s| s.as_str()).collect();
    let (centroids, secs_ml) = time_it(|| {
        scaled
            .matrix_assembly(&refs)
            .kmeans(p.k, p.iters, use_pjrt)
            .collect()
            .unwrap()
    });
    println!(
        "k-means ({}) in {:.1} ms:",
        if use_pjrt {
            "PJRT artifacts: L2 jax + L1 pallas"
        } else {
            "rust kernel — run `make artifacts` for the PJRT path"
        },
        secs_ml * 1e3
    );
    println!("{centroids}");
    Ok(())
}
