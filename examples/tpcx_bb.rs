//! TPCx-BB Q05 / Q25 / Q26 on both engines (paper §5.1, Fig. 11).
//!
//!     cargo run --release --example tpcx_bb -- --sf 1 --workers 4 [--skew 1.5]

use hiframes::baseline::sparklike::SparkLike;
use hiframes::bigbench::{self, q05, q25, q26};
use hiframes::frame::HiFrames;
use hiframes::metrics::time_it;

fn arg(name: &str, default: f64) -> f64 {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let sf = arg("--sf", 1.0);
    let workers = arg("--workers", hiframes::config::default_workers() as f64) as usize;
    let skew = arg("--skew", 0.0);
    println!("TPCx-BB: sf={sf} workers={workers} skew={skew}");

    let db = bigbench::generate(&bigbench::GenOptions {
        scale_factor: sf,
        click_skew: skew,
        seed: 42,
    });
    println!(
        "generated: store_sales={} web_sales={} clicks={} items={} customers={}",
        db.store_sales.num_rows(),
        db.web_sales.num_rows(),
        db.web_clickstream.num_rows(),
        db.item.num_rows(),
        db.customer.num_rows()
    );

    let hf = HiFrames::with_workers(workers);
    let eng = SparkLike::new(workers, workers * 2);

    // ---- Q26 ----------------------------------------------------------------
    let p26 = q26::Q26Params::default();
    let (ours, h) = time_it(|| {
        q26::hiframes_relational(&hf, &db, &p26)
            .collect()
            .unwrap()
    });
    let (theirs, s) = time_it(|| {
        eng.collect(&q26::sparklike_relational(&eng, &db, &p26).unwrap())
            .unwrap()
    });
    println!(
        "Q26  hiframes {:8.1} ms   sparklike {:8.1} ms   speedup {:4.1}x   rows {} / {}",
        h * 1e3,
        s * 1e3,
        s / h,
        ours.num_rows(),
        theirs.num_rows()
    );

    // ---- Q25 ----------------------------------------------------------------
    let (ours, h) = time_it(|| q25::hiframes_relational(&hf, &db).collect().unwrap());
    let (theirs, s) = time_it(|| {
        eng.collect(&q25::sparklike_relational(&eng, &db).unwrap())
            .unwrap()
    });
    println!(
        "Q25  hiframes {:8.1} ms   sparklike {:8.1} ms   speedup {:4.1}x   rows {} / {}",
        h * 1e3,
        s * 1e3,
        s / h,
        ours.num_rows(),
        theirs.num_rows()
    );

    // ---- Q05 ----------------------------------------------------------------
    let (ours, h) = time_it(|| q05::hiframes_relational(&hf, &db).collect().unwrap());
    let (theirs, s) = time_it(|| {
        eng.collect(&q05::sparklike_relational(&eng, &db).unwrap())
            .unwrap()
    });
    println!(
        "Q05  hiframes {:8.1} ms   sparklike {:8.1} ms   speedup {:4.1}x   rows {} / {}",
        h * 1e3,
        s * 1e3,
        s / h,
        ours.num_rows(),
        theirs.num_rows()
    );
    if skew > 0.0 {
        let (factor, counts) = q05::join_imbalance(&db, workers)?;
        println!("Q05 skewed join imbalance: max/mean = {factor:.2} (per-rank rows {counts:?})");
    }
    Ok(())
}
