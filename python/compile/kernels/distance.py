"""L1 Pallas kernel: tiled pairwise squared distances.

The k-means hot-spot. For points ``x (N, D)`` and centroids ``c (K, D)``
computes ``dist[i, j] = ||x_i - c_j||^2`` via the MXU-friendly factored form

    dist = |x|^2 - 2 x c^T + |c|^2

so the inner loop is a matmul (``jnp.dot`` with
``preferred_element_type=float32``) that maps onto the TPU MXU systolic
array. The grid tiles N into ``TILE_N``-row blocks; each grid step holds one
``(TILE_N, D)`` point tile plus the full ``(K, D)`` centroid block in VMEM —
for the shipped config (TILE_N=512, D<=64, K<=64, f32) that is
``512*64*4 + 64*64*4 + 512*64*4 = ~0.28 MiB``, far under the ~16 MiB VMEM
budget, leaving room for double buffering (see DESIGN.md §Hardware-Adaptation
and EXPERIMENTS.md §Perf for the utilization estimate).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of points processed per grid step.
TILE_N = 512


def _distance_kernel(x_ref, c_ref, o_ref):
    """One grid step: distances of a point tile against all centroids."""
    x = x_ref[...]  # (TILE_N, D)
    c = c_ref[...]  # (K, D)
    # |x|^2 row norms, |c|^2 col norms, cross term on the MXU
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (TILE_N, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, K)
    cross = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # (TILE_N, K)
    # clamp tiny negatives from cancellation so argmin/sqrt stay safe
    o_ref[...] = jnp.maximum(x2 - 2.0 * cross + c2, 0.0)


@functools.partial(jax.jit, static_argnames=())
def pairwise_distances(x, c):
    """``(N, D), (K, D) -> (N, K)`` squared distances via the Pallas kernel.

    N must be a multiple of TILE_N or smaller than it (single block).
    """
    n, d = x.shape
    k, d2 = c.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    tile = min(TILE_N, n)
    assert n % tile == 0, f"N={n} not a multiple of tile {tile}"
    grid = (n // tile,)
    return pl.pallas_call(
        _distance_kernel,
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),  # stream point tiles
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # centroids resident
        ],
        out_specs=pl.BlockSpec((tile, k), lambda i: (i, 0)),
        interpret=True,
    )(x.astype(jnp.float32), c.astype(jnp.float32))
