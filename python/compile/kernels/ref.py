"""Pure-jnp/numpy oracles for the Pallas kernels and the L2 model.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert the kernels match these within float tolerance, and the rust side's
`ops::stencil_serial` / `ml::kmeans` implement the same formulas.
"""

import jax.numpy as jnp
import numpy as np


def pairwise_distances_ref(x, c):
    """(N, D), (K, D) -> (N, K) squared euclidean distances."""
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def wma_ref(x, w):
    """Radius-1 weighted window with truncated+renormalized edges."""
    x = np.asarray(x, np.float64)
    w = np.asarray(w, np.float64)
    n = x.shape[0]
    wtotal = w.sum()
    out = np.zeros(n)
    for i in range(n):
        acc, used = 0.0, 0.0
        for j, wj in enumerate(w):
            idx = i + j - 1
            if 0 <= idx < n:
                acc += wj * x[idx]
                used += wj
        out[i] = acc * wtotal / used if used != 0.0 else 0.0
    return out.astype(np.float32)


def kmeans_step_ref(points, mask, centroids):
    """One masked k-means step: (sums (K,D), counts (K,), inertia)."""
    points = np.asarray(points, np.float64)
    mask = np.asarray(mask, np.float64)
    centroids = np.asarray(centroids, np.float64)
    k, d = centroids.shape
    dist = ((points[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    assign = dist.argmin(axis=1)
    sums = np.zeros((k, d))
    counts = np.zeros(k)
    inertia = 0.0
    for i, a in enumerate(assign):
        if mask[i] > 0:
            sums[a] += points[i]
            counts[a] += 1
            inertia += dist[i, a]
    return sums.astype(np.float32), counts.astype(np.float32), np.float32(inertia)


def sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


def logreg_loss_grad_ref(xs, ys, mask, w):
    """Masked-sum logistic loss and gradient (not averaged: partials)."""
    xs = np.asarray(xs, np.float64)
    ys = np.asarray(ys, np.float64)
    mask = np.asarray(mask, np.float64)
    w = np.asarray(w, np.float64)
    d = xs.shape[1]
    z = xs @ w[:d] + w[d]
    p = sigmoid(z)
    pc = np.clip(p, 1e-7, 1.0 - 1e-7)
    loss = -np.sum(mask * (ys * np.log(pc) + (1 - ys) * np.log(1 - pc)))
    err = (p - ys) * mask
    grad = np.concatenate([xs.T @ err, [err.sum()]])
    return np.float32(loss), grad.astype(np.float32)


def standardize_ref(x):
    """The paper's Q26 feature scaling: (x - mean) / var."""
    x = np.asarray(x, np.float64)
    m = x.mean()
    v = x.var()
    return ((x - m) / v).astype(np.float32)
