"""L1 Pallas kernel: 1-D weighted window stencil (SMA/WMA).

Semantics shared with the whole stack (rust `ops::stencil`, `ref.py`, the
serial baselines): radius-1 window with weights ``w = (w0, w1, w2)``;
interior points get ``w0*x[i-1] + w1*x[i] + w2*x[i+1]``; the two edge points
use the truncated window renormalized by the weight mass actually used:

    out[i] = (sum_valid w*x) * (sum_all w) / (sum_valid w)

The kernel tiles the series into VMEM blocks; each grid step loads its block
plus a one-element halo on each side (expressed by loading the *full* row
block and shifting — on real TPU the HBM->VMEM pipeline would stream
overlapping blocks via BlockSpec index_map; with interpret=True we keep one
block per grid step and do the halo with jnp.roll + masking, which lowers to
identical HLO numerics).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wma_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...]  # (N,)
    w = w_ref[...]  # (3,)
    n = x.shape[0]
    idx = jnp.arange(n)
    # neighbor loads via roll; edges masked off below
    left = jnp.roll(x, 1)
    right = jnp.roll(x, -1)
    has_left = idx > 0
    has_right = idx < n - 1
    num = (
        jnp.where(has_left, w[0] * left, 0.0)
        + w[1] * x
        + jnp.where(has_right, w[2] * right, 0.0)
    )
    used = jnp.where(has_left, w[0], 0.0) + w[1] + jnp.where(has_right, w[2], 0.0)
    wtotal = w[0] + w[1] + w[2]
    o_ref[...] = num * wtotal / used


def wma(x, w):
    """``(N,), (3,) -> (N,)`` weighted moving average via the Pallas kernel."""
    (n,) = x.shape
    return pl.pallas_call(
        _wma_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))
