"""L2 — the JAX analytics model (build-time only; never on the query path).

Entry points, each calling into the L1 Pallas kernels and AOT-lowered by
`aot.py`:

* ``kmeans_step(points, mask, centroids)`` — one masked k-means step
  returning per-cluster (sums, counts) partials + inertia. Distances come
  from the Pallas kernel (`kernels.distance`); the caller (rust `ml`)
  allreduces the partials in distributed mode and performs the division.
* ``logreg_step(xs, ys, mask, w)`` — logistic-regression loss + gradient.
  The gradient is produced by ``jax.grad`` (fwd+bwd through XLA), so the
  lowered artifact contains the backward pass — no Python at runtime.
* ``wma(x, w)`` — the Pallas stencil kernel (SMA = equal weights).
* ``standardize(x)`` — the paper's Q26 feature scaling `(x - mean)/var`.
"""

import jax
import jax.numpy as jnp

from .kernels.distance import pairwise_distances
from .kernels.stencil import wma as wma_kernel


def kmeans_step(points, mask, centroids):
    """One k-means assignment + partial-update step.

    points (N, D) f32, mask (N,) f32 in {0,1}, centroids (K, D) f32
    -> (sums (K, D), counts (K,), inertia ())
    """
    k = centroids.shape[0]
    dist = pairwise_distances(points, centroids)  # Pallas kernel (N, K)
    assign = jnp.argmin(dist, axis=1)
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    onehot = onehot * mask[:, None]
    sums = jnp.dot(onehot.T, points, preferred_element_type=jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    inertia = jnp.sum(jnp.min(dist, axis=1) * mask)
    return sums, counts, inertia


def _logreg_loss(w, xs, ys, mask):
    d = xs.shape[1]
    z = jnp.dot(xs, w[:d]) + w[d]
    p = jax.nn.sigmoid(z)
    pc = jnp.clip(p, 1e-7, 1.0 - 1e-7)
    return -jnp.sum(mask * (ys * jnp.log(pc) + (1.0 - ys) * jnp.log(1.0 - pc)))


def logreg_step(xs, ys, mask, w):
    """Loss + gradient partials via jax.grad (the lowered bwd pass).

    xs (N, D), ys (N,), mask (N,), w (D+1,) -> (grad (D+1,), loss ())
    """
    loss, grad = jax.value_and_grad(_logreg_loss)(w, xs, ys, mask)
    return grad, loss


def wma(x, w):
    """Weighted moving average via the Pallas stencil kernel."""
    return wma_kernel(x, w)


def standardize(x):
    """(x - mean) / var — population variance, matching rust `var_f64`."""
    x = x.astype(jnp.float32)
    m = jnp.mean(x)
    v = jnp.mean((x - m) * (x - m))
    return (x - m) / v
