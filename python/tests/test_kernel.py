"""L1 kernel correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes and values; fixed cases pin the paper's examples
(Table 1 WMA weights) and edge semantics.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.distance import pairwise_distances, TILE_N
from compile.kernels.stencil import wma
from compile.kernels import ref


# ---------------------------------------------------------------------------
# pairwise distances
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 2, 7, 32, 512, 1024]),
    d=st.integers(min_value=1, max_value=8),
    k=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_distance_kernel_matches_ref(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32) * 3.0
    c = rng.normal(size=(k, d)).astype(np.float32) * 3.0
    got = np.asarray(pairwise_distances(x, c))
    want = np.asarray(ref.pairwise_distances_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_distance_kernel_tiled_path():
    # exercise the multi-block grid (N > TILE_N)
    rng = np.random.default_rng(0)
    n = TILE_N * 3
    x = rng.normal(size=(n, 4)).astype(np.float32)
    c = rng.normal(size=(5, 4)).astype(np.float32)
    got = np.asarray(pairwise_distances(x, c))
    want = np.asarray(ref.pairwise_distances_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_distance_nonneg_and_zero_diagonal():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 3)).astype(np.float32)
    got = np.asarray(pairwise_distances(x, x))
    assert (got >= 0).all()
    np.testing.assert_allclose(np.diag(got), 0.0, atol=1e-4)


def test_distance_rejects_dim_mismatch():
    x = np.zeros((4, 3), np.float32)
    c = np.zeros((2, 5), np.float32)
    with pytest.raises(AssertionError):
        pairwise_distances(x, c)


# ---------------------------------------------------------------------------
# wma stencil
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    w0=st.floats(min_value=0.05, max_value=2.0),
    w1=st.floats(min_value=0.05, max_value=2.0),
    w2=st.floats(min_value=0.05, max_value=2.0),
)
def test_wma_kernel_matches_ref(n, seed, w0, w1, w2):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    w = np.array([w0, w1, w2], np.float32)
    got = np.asarray(wma(x, w))
    want = ref.wma_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_wma_paper_weights():
    # Table 1's WMA: (x[-1] + 2 x[0] + x[1]) / 4
    x = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    w = np.array([0.25, 0.5, 0.25], np.float32)
    got = np.asarray(wma(x, w))
    # interior: exact weighted average
    np.testing.assert_allclose(got[1], 2.0, rtol=1e-5)
    np.testing.assert_allclose(got[2], 3.0, rtol=1e-5)


def test_sma_is_wma_with_equal_weights():
    x = np.arange(10, dtype=np.float32)
    w = np.array([1 / 3, 1 / 3, 1 / 3], np.float32)
    got = np.asarray(wma(x, w))
    # interior equals the centered mean
    np.testing.assert_allclose(got[1:-1], x[1:-1], rtol=1e-5)
    # edges: truncated + renormalized -> mean of the two available points
    np.testing.assert_allclose(got[0], 0.5, atol=1e-5)
    np.testing.assert_allclose(got[-1], 8.5, atol=1e-5)
