"""L2 model correctness: jax entry points vs oracles + shape checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 32, 100]),
    d=st.integers(min_value=1, max_value=6),
    k=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kmeans_step_matches_ref(n, d, k, seed):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, d)).astype(np.float32)
    mask = (rng.random(n) > 0.3).astype(np.float32)
    cents = rng.normal(size=(k, d)).astype(np.float32)
    sums, counts, inertia = model.kmeans_step(pts, mask, cents)
    rsums, rcounts, rinertia = ref.kmeans_step_ref(pts, mask, cents)
    np.testing.assert_allclose(np.asarray(counts), rcounts, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sums), rsums, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(inertia), float(rinertia), rtol=1e-3, atol=1e-2)


def test_kmeans_step_mask_excludes_rows():
    pts = np.array([[0.0, 0.0], [100.0, 100.0]], np.float32)
    mask = np.array([1.0, 0.0], np.float32)
    cents = np.array([[0.0, 0.0]], np.float32)
    sums, counts, inertia = model.kmeans_step(pts, mask, cents)
    assert float(counts[0]) == 1.0
    np.testing.assert_allclose(np.asarray(sums), [[0.0, 0.0]], atol=1e-6)
    np.testing.assert_allclose(float(inertia), 0.0, atol=1e-6)


def test_kmeans_converges_on_blobs():
    rng = np.random.default_rng(3)
    a = rng.normal(size=(64, 2)) * 0.2
    b = rng.normal(size=(64, 2)) * 0.2 + 10.0
    pts = np.vstack([a, b]).astype(np.float32)
    mask = np.ones(128, np.float32)
    cents = pts[:2].copy()
    for _ in range(15):
        sums, counts, inertia = model.kmeans_step(pts, mask, cents)
        counts = np.maximum(np.asarray(counts), 1e-9)
        cents = (np.asarray(sums) / counts[:, None]).astype(np.float32)
    got = sorted(cents[:, 0].tolist())
    assert abs(got[0]) < 1.0 and abs(got[1] - 10.0) < 1.0
    assert float(inertia) < 50.0


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 64]),
    d=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_logreg_grad_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, d)).astype(np.float32)
    ys = (rng.random(n) > 0.5).astype(np.float32)
    mask = (rng.random(n) > 0.2).astype(np.float32)
    w = rng.normal(size=d + 1).astype(np.float32) * 0.1
    grad, loss = model.logreg_step(xs, ys, mask, w)
    rloss, rgrad = ref.logreg_loss_grad_ref(xs, ys, mask, w)
    np.testing.assert_allclose(float(loss), float(rloss), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(grad), rgrad, rtol=1e-3, atol=1e-3)


def test_logreg_grad_is_true_gradient():
    # numeric gradient check on the jax.grad-produced artifact math
    rng = np.random.default_rng(5)
    xs = rng.normal(size=(16, 3)).astype(np.float32)
    ys = (rng.random(16) > 0.5).astype(np.float32)
    mask = np.ones(16, np.float32)
    w = rng.normal(size=4).astype(np.float32) * 0.1
    grad, _ = model.logreg_step(xs, ys, mask, w)
    eps = 1e-3
    for i in range(4):
        wp, wm = w.copy(), w.copy()
        wp[i] += eps
        wm[i] -= eps
        _, lp = model.logreg_step(xs, ys, mask, wp)
        _, lm = model.logreg_step(xs, ys, mask, wm)
        num = (float(lp) - float(lm)) / (2 * eps)
        assert abs(num - float(np.asarray(grad)[i])) < 5e-2, f"w[{i}]"


def test_standardize_matches_ref():
    rng = np.random.default_rng(7)
    x = (rng.normal(size=256) * 5 + 3).astype(np.float32)
    got = np.asarray(model.standardize(jnp.asarray(x)))
    want = ref.standardize_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_entry_points_lower_to_hlo():
    # the aot path must produce parseable HLO text for every entry
    from compile.aot import lower_entry
    f32 = jnp.float32
    hlo = lower_entry(
        model.kmeans_step,
        (
            jax.ShapeDtypeStruct((64, 3), f32),
            jax.ShapeDtypeStruct((64,), f32),
            jax.ShapeDtypeStruct((4, 3), f32),
        ),
    )
    assert "HloModule" in hlo
    hlo = lower_entry(
        model.wma,
        (jax.ShapeDtypeStruct((128,), f32), jax.ShapeDtypeStruct((3,), f32)),
    )
    assert "HloModule" in hlo
